//! Fit/predict model lifecycle — the public clustering API.
//!
//! The paper's whole argument is that the expensive part (subcluster +
//! global k-means) runs **once**, producing K centers that are then
//! cheap to use.  This module makes that split first-class:
//!
//! * [`ClusterModel`] — anything that can run the expensive fit:
//!   [`KMeans`] (Lloyd's), [`MiniBatchKMeans`], [`BisectingKMeans`],
//!   and the paper's [`SubclusterPipeline`].  `fit(&Dataset)` returns…
//! * [`FittedModel`] — a persistent artifact owning the centers, the
//!   fitted [`crate::data::MinMaxScaler`] (when the fit scaled), and
//!   the fit metadata, with versioned JSON save/load and
//!   engine-backed `predict`/`predict_batch` (bit-identical to
//!   [`crate::pipeline::assign_full`]).
//! * [`ModelSpec`] — algorithm-by-name dispatch shared by the CLI
//!   `fit` subcommand and the server's `fit` request, so both front
//!   ends build models through exactly one code path.
//!
//! Fit once, predict many:
//!
//! ```no_run
//! use parsample::data::builtin;
//! use parsample::model::{ClusterModel, FittedModel};
//! use parsample::pipeline::{PipelineConfig, SubclusterPipeline};
//!
//! let data = builtin::iris();
//! let cfg = PipelineConfig::builder().final_k(3).build().unwrap();
//! let model = SubclusterPipeline::new(cfg).fit(&data).unwrap();
//! model.save("iris.model.json").unwrap();
//! // …later, anywhere, without re-clustering:
//! let model = FittedModel::load("iris.model.json").unwrap();
//! let label = model.predict(data.row(0)).unwrap();
//! # let _ = label;
//! ```

pub mod artifact;

pub use crate::cluster::engine::EngineOpts;
pub use artifact::{FitMeta, FittedModel, Prediction, SourcePrediction, MODEL_FORMAT, MODEL_VERSION};

use crate::cluster::kmeans::{lloyd, KMeansConfig, KMeansResult};
use crate::cluster::{BisectingKMeans, InitMethod, InitParams, MiniBatchKMeans};
use crate::data::scaling::MinMaxScaler;
use crate::data::source::{collect_dataset, DataSource, SliceSource};
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::partition::Scheme;
use crate::pipeline::{PipelineConfig, SubclusterPipeline};

/// Anything that can run the expensive clustering once and hand back a
/// reusable [`FittedModel`].
///
/// Contrast with [`crate::cluster::Clusterer`], which returns raw
/// centers/labels for the caller to manage: a `ClusterModel` fit
/// produces a self-describing artifact that can be saved, loaded,
/// registered in a server, and asked for predictions long after the
/// training data is gone.
pub trait ClusterModel {
    /// Algorithm name recorded in the artifact (and accepted by
    /// [`ModelSpec`]).
    fn algorithm(&self) -> &'static str;

    /// Run the fit on `data` and package the result.
    fn fit(&self, data: &Dataset) -> Result<FittedModel>;

    /// Run the fit over a streaming [`DataSource`] — the out-of-core
    /// entry point.  The contract (pinned by
    /// `rust/tests/stream_parity.rs`): for a source backed by the same
    /// bytes as a resident [`Dataset`], `fit_source` produces a
    /// bit-identical artifact to [`ClusterModel::fit`] at every source
    /// chunk size and [`EngineOpts`] setting.
    ///
    /// The default implementation is the *documented spill fallback*:
    /// algorithms that genuinely need random access (Lloyd's and
    /// bisecting k-means revisit every row every iteration) drain the
    /// source into a resident dataset and fit that.  True streaming
    /// consumers override it: [`MiniBatchKMeans`] eats the stream in
    /// batches, [`SubclusterPipeline`] scatters it into its partition
    /// groups in a single pass
    /// ([`crate::pipeline::stream`]).
    fn fit_source(&self, src: &mut dyn DataSource) -> Result<FittedModel> {
        src.reset()?;
        let ds = collect_dataset(src)?;
        self.fit(&ds)
    }
}

/// Lloyd's k-means as a [`ClusterModel`] (the k lives in the config).
#[derive(Debug, Clone, Default)]
pub struct KMeans {
    pub config: KMeansConfig,
}

impl KMeans {
    /// Default-config Lloyd's with `k` centers.
    pub fn new(k: usize) -> KMeans {
        KMeans { config: KMeansConfig { k, ..Default::default() } }
    }

    pub fn with_engine_opts(mut self, opts: EngineOpts) -> KMeans {
        self.config = self.config.with_engine_opts(opts);
        self
    }
}

/// Package one [`KMeansResult`] as an artifact.
fn artifact_from_result(
    algorithm: &str,
    data: &Dataset,
    r: KMeansResult,
    engine: EngineOpts,
    init: InitMethod,
    init_params: InitParams,
    scaler: Option<MinMaxScaler>,
) -> Result<FittedModel> {
    FittedModel::new(
        FitMeta {
            algorithm: algorithm.to_string(),
            k: r.counts.len(),
            dims: data.dims(),
            trained_on: data.len(),
            inertia: r.inertia,
            iterations: r.iterations,
            engine,
            init,
            init_params,
        },
        r.centers,
        scaler,
    )
}

impl ClusterModel for KMeans {
    fn algorithm(&self) -> &'static str {
        "kmeans"
    }

    fn fit(&self, data: &Dataset) -> Result<FittedModel> {
        let r = lloyd(data.as_slice(), data.dims(), &self.config)?;
        artifact_from_result(
            self.algorithm(),
            data,
            r,
            self.config.engine_opts(),
            self.config.init,
            self.config.init_params(),
            None,
        )
    }
}

impl ClusterModel for MiniBatchKMeans {
    fn algorithm(&self) -> &'static str {
        "minibatch-kmeans"
    }

    /// The resident fit *is* the streaming fit over an in-memory
    /// source (zero-copy), so `fit` and [`ClusterModel::fit_source`]
    /// are one algorithm and bit-identical by construction.  (The
    /// random-batch resident variant stays available as
    /// [`MiniBatchKMeans::run`] for the ablation benches.)
    fn fit(&self, data: &Dataset) -> Result<FittedModel> {
        self.fit_source(&mut SliceSource::of(data))
    }

    /// True streaming consumer: batches are consecutive windows pulled
    /// straight off the source ([`MiniBatchKMeans::fit_stream`]).
    fn fit_source(&self, src: &mut dyn DataSource) -> Result<FittedModel> {
        let dims = src.dims();
        let r = self.fit_stream(src)?;
        FittedModel::new(
            FitMeta {
                algorithm: self.algorithm().to_string(),
                k: r.counts.len(),
                dims,
                trained_on: r.rows,
                inertia: r.inertia,
                iterations: r.iterations,
                engine: self.engine_opts(),
                init: self.init,
                init_params: self.init_params(),
            },
            r.centers,
            None,
        )
    }
}

impl ClusterModel for BisectingKMeans {
    fn algorithm(&self) -> &'static str {
        "bisecting-kmeans"
    }

    fn fit(&self, data: &Dataset) -> Result<FittedModel> {
        let r = self.run(data.as_slice(), data.dims(), self.k)?;
        artifact_from_result(
            self.algorithm(),
            data,
            r,
            self.engine_opts(),
            self.init,
            self.init_params(),
            None,
        )
    }
}

impl ClusterModel for SubclusterPipeline {
    fn algorithm(&self) -> &'static str {
        "pipeline"
    }

    fn fit(&self, data: &Dataset) -> Result<FittedModel> {
        let r = self.run(data)?;
        let cfg = self.config();
        // The pipeline scales for the partition stage only; refit the
        // scaler (two O(M·D) corner scans, no copy) so the artifact
        // carries the fitted transform alongside the centers.
        let scaler = if cfg.scale {
            let mut s = MinMaxScaler::new();
            s.fit(data)?;
            Some(s)
        } else {
            None
        };
        FittedModel::new(
            FitMeta {
                algorithm: self.algorithm().to_string(),
                k: r.counts.len(),
                dims: data.dims(),
                trained_on: data.len(),
                inertia: r.inertia,
                iterations: r.global_iterations,
                engine: cfg.engine_opts(),
                init: cfg.init,
                init_params: cfg.init_params(),
            },
            r.centers,
            scaler,
        )
    }

    /// True streaming consumer: the paper's subdivision becomes a
    /// single-pass scatter of the stream into the partition groups
    /// ([`crate::pipeline::stream`]); bit-identical to the resident
    /// fit on the same bytes (equal scheme / PJRT backend take the
    /// documented spill fallback inside `run_source`).
    fn fit_source(&self, src: &mut dyn DataSource) -> Result<FittedModel> {
        let dims = src.dims();
        let r = self.run_source(src)?;
        FittedModel::new(
            FitMeta {
                algorithm: self.algorithm().to_string(),
                k: r.counts.len(),
                dims,
                trained_on: r.rows,
                inertia: r.inertia,
                iterations: r.global_iterations,
                engine: self.config().engine_opts(),
                init: self.config().init,
                init_params: self.config().init_params(),
            },
            r.centers,
            r.scaler,
        )
    }
}

/// Algorithm-by-name model construction — one dispatch shared by the
/// CLI `fit` subcommand and the server's `fit` request.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// `kmeans` | `minibatch` | `bisecting` | `pipeline` (plus the
    /// long spellings the artifacts record).
    pub algorithm: String,
    /// Requested number of centers.
    pub k: usize,
    /// Algorithm-specific iteration knob: Lloyd `max_iters`,
    /// mini-batch rounds, bisecting per-split iterations, or the
    /// pipeline's global iterations.  `None` keeps each default.
    pub iters: Option<usize>,
    pub seed: u64,
    /// Engine knobs for the fit (recorded as provenance).
    pub engine: EngineOpts,
    /// Seeding method (`None` keeps each algorithm's default —
    /// `Auto` for kmeans/minibatch/bisecting/pipeline).
    pub init: Option<InitMethod>,
    /// k-means‖ knobs (oversampling factor, round override); the
    /// default reproduces the automatic behavior bit-for-bit.
    pub init_params: InitParams,
    /// Pipeline-only: partitioning scheme.
    pub scheme: Option<Scheme>,
    /// Pipeline-only: the paper's compression value c.
    pub compression: Option<f32>,
    /// Pipeline-only: sub-region count G.
    pub num_groups: Option<usize>,
    /// Pipeline-only: distributed local stage across a remote worker
    /// fleet (`None` = local threads; bit-identical either way).
    pub remote: Option<crate::coordinator::remote::RemoteConfig>,
}

impl ModelSpec {
    pub fn new(algorithm: impl Into<String>, k: usize) -> ModelSpec {
        ModelSpec {
            algorithm: algorithm.into(),
            k,
            iters: None,
            seed: 0,
            engine: EngineOpts::default(),
            init: None,
            init_params: InitParams::default(),
            scheme: None,
            compression: None,
            num_groups: None,
            remote: None,
        }
    }

    /// Construct the [`ClusterModel`] this spec names (shared by the
    /// resident and streaming fit entry points).
    pub fn build_model(&self) -> Result<Box<dyn ClusterModel>> {
        match self.algorithm.as_str() {
            "kmeans" => {
                let mut cfg = KMeansConfig { k: self.k, seed: self.seed, ..Default::default() }
                    .with_engine_opts(self.engine);
                if let Some(it) = self.iters {
                    cfg.max_iters = it;
                }
                if let Some(i) = self.init {
                    cfg.init = i;
                }
                cfg.init_oversample = self.init_params.oversample;
                cfg.init_rounds = self.init_params.rounds;
                Ok(Box::new(KMeans { config: cfg }))
            }
            "minibatch" | "minibatch-kmeans" => {
                let mut cfg = MiniBatchKMeans { k: self.k, seed: self.seed, ..Default::default() }
                    .with_engine_opts(self.engine);
                if let Some(it) = self.iters {
                    cfg.iters = it;
                }
                if let Some(i) = self.init {
                    cfg.init = i;
                }
                cfg.init_oversample = self.init_params.oversample;
                cfg.init_rounds = self.init_params.rounds;
                Ok(Box::new(cfg))
            }
            "bisecting" | "bisecting-kmeans" => {
                let mut cfg = BisectingKMeans { k: self.k, seed: self.seed, ..Default::default() }
                    .with_engine_opts(self.engine);
                if let Some(it) = self.iters {
                    cfg.split_iters = it;
                }
                if let Some(i) = self.init {
                    cfg.init = i;
                }
                cfg.init_oversample = self.init_params.oversample;
                cfg.init_rounds = self.init_params.rounds;
                Ok(Box::new(cfg))
            }
            "pipeline" | "subcluster" | "subcluster-pipeline" => {
                let mut b = PipelineConfig::builder()
                    .final_k(self.k)
                    .seed(self.seed)
                    .engine(self.engine);
                if let Some(s) = self.scheme {
                    b = b.scheme(s);
                }
                if let Some(c) = self.compression {
                    b = b.compression(c);
                }
                if let Some(g) = self.num_groups {
                    b = b.num_groups(g);
                }
                if let Some(it) = self.iters {
                    b = b.global_iters(it);
                }
                if let Some(i) = self.init {
                    b = b.init(i);
                }
                b = b.init_oversample(self.init_params.oversample);
                if let Some(r) = self.init_params.rounds {
                    b = b.init_rounds(r);
                }
                if let Some(r) = &self.remote {
                    b = b.remote(r.clone());
                }
                Ok(Box::new(SubclusterPipeline::new(b.build()?)))
            }
            other => Err(Error::Model(format!(
                "unknown algorithm '{other}' (expected kmeans|minibatch|bisecting|pipeline)"
            ))),
        }
    }

    /// Build the model this spec names and fit it on `data`.
    pub fn fit(&self, data: &Dataset) -> Result<FittedModel> {
        self.build_model()?.fit(data)
    }

    /// Build the model this spec names and fit it over a streaming
    /// source — the CLI `fit --chunk-rows` path.  Bit-identical to
    /// [`ModelSpec::fit`] on the same bytes.
    pub fn fit_source(&self, src: &mut dyn DataSource) -> Result<FittedModel> {
        self.build_model()?.fit_source(src)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{make_blobs, BlobSpec};

    fn blobs(m: usize, k: usize, seed: u64) -> Dataset {
        make_blobs(&BlobSpec {
            num_points: m,
            num_clusters: k,
            dims: 2,
            std: 0.05,
            extent: 10.0,
            seed,
        })
        .unwrap()
    }

    #[test]
    fn kmeans_fit_produces_consistent_artifact() {
        let data = blobs(300, 3, 1);
        let model = KMeans::new(3).fit(&data).unwrap();
        assert_eq!(model.meta().algorithm, "kmeans");
        assert_eq!(model.k(), 3);
        assert_eq!(model.dims(), 2);
        assert_eq!(model.meta().trained_on, 300);
        assert!(model.meta().inertia.is_finite());
        assert!(model.meta().iterations >= 1);
        assert!(model.scaler().is_none());
        // predicting the training set reproduces the fit inertia
        let p = model.predict_dataset(&data).unwrap();
        assert_eq!(p.counts.iter().sum::<u32>(), 300);
        assert!((p.inertia - model.meta().inertia).abs() < 1e-6);
    }

    #[test]
    fn every_algorithm_fits_via_spec() {
        let data = blobs(400, 4, 2);
        for (name, recorded) in [
            ("kmeans", "kmeans"),
            ("minibatch", "minibatch-kmeans"),
            ("bisecting", "bisecting-kmeans"),
            ("pipeline", "pipeline"),
        ] {
            let mut spec = ModelSpec::new(name, 4);
            spec.num_groups = Some(4);
            spec.compression = Some(4.0);
            let model = spec.fit(&data).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(model.meta().algorithm, recorded, "{name}");
            assert_eq!(model.dims(), 2, "{name}");
            let p = model.predict_dataset(&data).unwrap();
            assert_eq!(p.labels.len(), 400, "{name}");
            assert_eq!(p.counts.iter().sum::<u32>(), 400, "{name}");
        }
        assert!(ModelSpec::new("dbscan", 3).fit(&data).is_err());
    }

    #[test]
    fn pipeline_fit_carries_the_scaler() {
        let data = blobs(500, 3, 3);
        let cfg = PipelineConfig::builder()
            .final_k(3)
            .num_groups(4)
            .compression(4.0)
            .build()
            .unwrap();
        let model = SubclusterPipeline::new(cfg).fit(&data).unwrap();
        let (mins, ranges) = model.scaler().expect("scale=true stores the scaler").params();
        assert_eq!(mins, &data.min_corner()[..]);
        let maxs = data.max_corner();
        for ((r, &lo), &hi) in ranges.iter().zip(mins).zip(&maxs) {
            assert!((r - (hi - lo)).abs() < 1e-6);
        }
        // scale=false → no scaler in the artifact
        let cfg = PipelineConfig::builder()
            .final_k(3)
            .num_groups(4)
            .compression(4.0)
            .scale(false)
            .build()
            .unwrap();
        let model = SubclusterPipeline::new(cfg).fit(&data).unwrap();
        assert!(model.scaler().is_none());
    }

    #[test]
    fn spec_iters_knob_reaches_each_algorithm() {
        let data = blobs(200, 2, 4);
        let mut spec = ModelSpec::new("kmeans", 2);
        spec.iters = Some(1);
        let m = spec.fit(&data).unwrap();
        assert_eq!(m.meta().iterations, 1);
        let mut spec = ModelSpec::new("pipeline", 2);
        spec.num_groups = Some(2);
        spec.compression = Some(4.0);
        spec.iters = Some(5);
        let m = spec.fit(&data).unwrap();
        assert_eq!(m.meta().iterations, 5);
    }

    #[test]
    fn spec_engine_opts_are_recorded() {
        let data = blobs(150, 2, 5);
        let mut spec = ModelSpec::new("kmeans", 2);
        spec.engine = EngineOpts::serial().with_workers(3);
        let m = spec.fit(&data).unwrap();
        assert_eq!(m.meta().engine.workers, 3);
        assert_eq!(m.engine_opts().workers, 3);
    }

    #[test]
    fn spec_init_knob_is_recorded_per_algorithm() {
        let data = blobs(200, 2, 6);
        for name in ["kmeans", "minibatch", "bisecting", "pipeline"] {
            let mut spec = ModelSpec::new(name, 2);
            spec.num_groups = Some(2);
            spec.compression = Some(4.0);
            spec.init = Some(InitMethod::KMeansParallel);
            let m = spec.fit(&data).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(m.meta().init, InitMethod::KMeansParallel, "{name}");
            // None keeps the algorithm default (Auto everywhere)
            let m = ModelSpec::new(name, 2).fit(&data);
            if let Ok(m) = m {
                assert_eq!(m.meta().init, InitMethod::Auto, "{name}");
            }
        }
    }
}
