//! # parsample
//!
//! A production-grade reproduction of **"A parallel sampling based
//! clustering"** (Sastry & Netti, 2014) as a three-layer
//! rust + JAX + Pallas system:
//!
//! * **L3 (this crate):** the paper's *host part* — dataset handling,
//!   feature scaling, the equal/unequal landmark partitioners, batching
//!   of sub-regions into fixed-shape device dispatches, a worker pool,
//!   the global clustering stage, a job server, CLI and telemetry —
//!   plus the traditional-k-means baseline every table compares against.
//! * **L2/L1 (python/, build-time only):** the *device part* — batched
//!   Lloyd iterations with a Pallas assignment kernel, AOT-lowered to
//!   HLO text that [`runtime`] loads and executes via PJRT.
//!
//! Quick start — fit once, predict many (see `examples/quickstart.rs`):
//!
//! ```no_run
//! use parsample::data::builtin;
//! use parsample::model::{ClusterModel, FittedModel};
//! use parsample::pipeline::{PipelineConfig, SubclusterPipeline};
//!
//! // the expensive part runs once…
//! let data = builtin::iris();
//! let cfg = PipelineConfig::builder()
//!     .num_groups(6)
//!     .compression(6.0)
//!     .final_k(3)
//!     .build()
//!     .unwrap();
//! let model = SubclusterPipeline::new(cfg).fit(&data).unwrap();
//! println!("fit inertia {}", model.meta().inertia);
//! model.save("iris.model.json").unwrap();
//!
//! // …and the artifact answers predictions from then on, here or in
//! // the serve-many job server (`parsample serve`, cmds fit/predict)
//! let model = FittedModel::load("iris.model.json").unwrap();
//! let assignment = model.predict(data.row(0)).unwrap();
//! println!("point 0 -> cluster {assignment}");
//! ```
//!
//! Out of core — when the dataset doesn't fit in RAM (the paper's
//! whole premise), pull it through a streaming [`data::DataSource`]
//! instead of loading it.  Fit and predict are **bit-identical** to
//! the resident paths at any chunk size:
//!
//! ```no_run
//! use parsample::cluster::MiniBatchKMeans;
//! use parsample::data::source::CsvSource;
//! use parsample::model::{ClusterModel, FittedModel};
//!
//! // fit without ever materializing the file (CLI: `fit --chunk-rows`)
//! let mut stream = CsvSource::open("huge.csv", None).unwrap().with_chunk_rows(8192);
//! let fitter = MiniBatchKMeans { k: 64, ..Default::default() };
//! let model = fitter.fit_source(&mut stream).unwrap();
//! model.save("huge.model.json").unwrap();
//!
//! // label the stream chunk by chunk; labels arrive incrementally
//! // (CLI `predict --chunk-rows --out` writes them to disk this way)
//! let model = FittedModel::load("huge.model.json").unwrap();
//! let mut stream = CsvSource::open("huge.csv", None).unwrap();
//! let p = model.predict_source(&mut stream, |labels| {
//!     // ship `labels` wherever they go — nothing is buffered whole
//!     let _ = labels;
//!     Ok(())
//! }).unwrap();
//! println!("labelled {} rows, inertia {}", p.rows, p.inertia);
//! ```
//!
//! Sources: in-memory ([`data::DatasetSource`] / [`data::SliceSource`],
//! zero-copy), streaming CSV ([`data::CsvSource`]), the `PSAMPLE1`
//! binary format ([`data::BinarySource`]), and the synthetic generator
//! ([`data::BlobSource`] — out-of-core benches need no giant files).
//! [`pipeline::SubclusterPipeline`] scatters a stream into its
//! partition groups in one pass (see [`pipeline::stream`]);
//! algorithms that need random access spill to a resident
//! [`data::Dataset`] via the documented
//! [`model::ClusterModel::fit_source`] fallback.
//!
//! [`model`] is the fit/predict lifecycle ([`model::ClusterModel`],
//! [`model::FittedModel`], shared [`cluster::EngineOpts`] knobs);
//! [`pipeline::SubclusterPipeline::run`] remains the single-shot,
//! labels-in-hand entry point.
//!
//! Distributed — the paper's fan-out across machines.  Start plain
//! `parsample serve` processes anywhere, then point a fit at them
//! (CLI: `fit --join HOST:PORT,...`); the coordinator ships each
//! partition group to the fleet as a `fit_group` wire call through a
//! fault-tolerant pool ([`coordinator::remote`]): per-dispatch
//! deadlines, retry/requeue with capped jittered backoff, quarantine +
//! ping-probe re-admission, and graceful degradation to local compute
//! when the whole fleet is gone.  Distributed results are
//! **bit-identical** to single-node, through every fault
//! (`rust/tests/distributed_fit.rs` injects them all):
//!
//! ```no_run
//! use parsample::coordinator::{RemoteConfig, SchedulerConfig};
//! use parsample::model::ClusterModel;
//! use parsample::pipeline::{PipelineConfig, SubclusterPipeline};
//! use parsample::server::Server;
//!
//! # let data = parsample::data::builtin::iris();
//! // two workers (in-process here; normally separate machines)…
//! let w1 = Server::start("127.0.0.1:0", SchedulerConfig::default()).unwrap();
//! let w2 = Server::start("127.0.0.1:0", SchedulerConfig::default()).unwrap();
//!
//! // …and a fit joined to both
//! let cfg = PipelineConfig::builder()
//!     .final_k(3)
//!     .remote(RemoteConfig::with_workers(vec![
//!         w1.addr().to_string(),
//!         w2.addr().to_string(),
//!     ]))
//!     .build()
//!     .unwrap();
//! let model = SubclusterPipeline::new(cfg).fit(&data).unwrap();
//! # let _ = model;
//! ```
//!
//! ## Initialization
//!
//! Every fit seeds through one knob, [`cluster::InitMethod`], threaded
//! from [`cluster::KMeansConfig`], [`cluster::MiniBatchKMeans`],
//! [`cluster::BisectingKMeans`], and [`pipeline::PipelineConfig`] up
//! to the config file (`pipeline.init`), the CLI (`--init`), and the
//! fit wire call — and recorded in every model artifact
//! ([`model::FitMeta::init`]) as provenance:
//!
//! * `firstk` / `random` — the trivial seeders (benches, baselines).
//! * `kmeans++` — the classic incremental seeder.  Its per-center
//!   min-distance sweep runs through the engine's parallel blocked
//!   pass, but the k draws themselves are inherently serial: an
//!   O(k·M·D) wall once k·M is large.
//! * `kmeans||` — k-means‖ (Bahmani et al., 2012), the engine-parallel
//!   seeder ([`cluster::init_parallel`]): ~log(M) rounds, each one
//!   engine-parallel min-distance sweep that oversamples ~2·k
//!   candidates via per-point Bernoulli draws, then a weighted
//!   k-means++ re-cluster of the tiny candidate set down to k.  The
//!   [`data::DataSource`] variant
//!   ([`cluster::initial_centers_source`]) streams one pass per round,
//!   so out-of-core fits seed from the whole stream, not a head
//!   window.
//! * `auto` (default) — `kmeans||` once k and k·M cross the crossover
//!   thresholds, `kmeans++` otherwise (small problems keep the classic
//!   bits).
//!
//! Seeding obeys the same reproducibility contract as the engine:
//! bit-identical centers at any worker count, tile kernel, and source
//! chunk size (per-(round, block) RNG streams, index-ordered f64 mass
//! folds; `rust/tests/init_parity.rs` pins the grid, and
//! `benches/init_quality.rs` tracks the wall-time win and seed
//! quality).
//!
//! ## Serving
//!
//! `parsample serve` is an event-driven model server.  One listener
//! speaks two wire formats, negotiated per connection by the first
//! bytes: JSON lines ([`server::protocol`]) and a length-prefixed
//! binary framing opened by the `PSF1` preamble ([`server::frame`] —
//! the full frame spec lives in that module's docs).  Binary predicts
//! ship `f32` rows in and `u32` labels out as raw little-endian bits,
//! so no text roundtrip ever touches the numbers; `--protocol
//! auto|jsonl|binary` (config: `server.protocol`) pins one format.
//!
//! Connections are served by a readiness **reactor**
//! (`server/reactor.rs`): one thread drives accept/read/write over
//! non-blocking sockets via `poll(2)`, so idle connections cost a
//! table slot instead of a parked thread.  Slow consumers hit a
//! bounded per-connection write queue and have their read side paused
//! (`backpressure` counter) rather than buffering without limit;
//! heavy jobs (`cluster`/`fit`/`fit_group`) still run on their own
//! threads behind the fit gate.  `--no-reactor` (config:
//! `server.reactor = false`) falls back to the legacy
//! thread-per-connection loop, which answers byte-identically.
//!
//! Predicts arriving within `--coalesce-us N` (config:
//! `server.coalesce_us`, reactor only) are **coalesced** into one
//! engine pass per model ([`server`]'s `batch` module).  Because the
//! engine's reduction is blocked and order-deterministic, the packed
//! pass replays each request's label slice, count bins, and f64
//! inertia fold exactly — coalesced replies are bit-identical to
//! per-request execution, which is pinned by
//! `rust/tests/serve_concurrency.rs` across {JSON, binary} ×
//! {coalescing on, off} × {reactor, legacy}.  Serving counters
//! (connections, decoded frames, batch sizes, backpressure episodes —
//! [`telemetry::ServeStats`]) ride the `stats` command next to the
//! scheduler's, and every accept/close/batch/backpressure occurrence
//! is a reason-tagged [`telemetry::events::EventLog`] event.
//! `benches/serve_load.rs` tracks predicts/s and tail latency across
//! protocol × connection count × coalescing (`BENCH_serve.json` in
//! CI).
//!
//! ## Invariants
//!
//! The guarantees above are not prose: each one is mechanically
//! enforced by the in-tree linter ([`analysis`], run as
//! `cargo run --bin parsample-lint`, a blocking CI gate).  The
//! contracts and their rule ids:
//!
//! * **Determinism** — files on the bit-exact path (`cluster/engine`,
//!   `kernel/*`, `distance`, the `coordinator::remote` merge) must
//!   carry a comment starting with the marker `CONTRACT: bit-exact`
//!   (`contract-annotation`), and inside a contract region the lint
//!   forbids `HashMap`/`HashSet` iteration order, `Instant`/
//!   `SystemTime`, thread-identity logic, and unordered float
//!   reductions like `.sum()` (`contract-forbidden`).  An inner doc
//!   comment (`//!` form) scopes the whole file; a plain `//` comment
//!   scopes the next block.
//! * **Safety** — every `unsafe` block or fn needs an adjacent
//!   `// SAFETY:` comment stating the invariant that makes it sound
//!   (`unsafe-safety`).
//! * **Concurrency** — condvar waits must sit inside a `while`/`loop`
//!   re-check because wakeups are spurious (`condvar-wait-while`), and
//!   every `.lock()` must either handle poisoning
//!   (`.unwrap_or_else(|p| p.into_inner())`, `.map_err(...)`) or
//!   document the abort policy with an `.expect("... poisoned")`
//!   message (`mutex-poison-doc`).
//! * **No panic paths** — non-test `server/` and `coordinator/` code
//!   must not `.unwrap()`, `.expect()`, `panic!`, `todo!`, or
//!   `unimplemented!`; errors travel the typed [`Error`] paths
//!   (`no-panic-path`).  Poisoning-policy expects are the one
//!   sanctioned exception.
//! * **Wire coverage** — every command in `server/protocol.rs` must be
//!   registered in its `WIRE_COMMANDS` table with a parse arm, an
//!   encode fn, and named roundtrip tests that exist
//!   (`protocol-coverage`).  The same pass runs over the binary
//!   protocol: `server/frame.rs` commands must be registered in
//!   `FRAME_COMMANDS` with an `opcode_of` arm, their encode fn, and
//!   roundtrip tests.
//!
//! The per-file rules above are joined by three **whole-crate** rules
//! that walk the item-level call graph the linter builds across every
//! `.rs` file in the tree ([`analysis::GraphData`], exportable as
//! JSONL via `parsample-lint --graph-out`):
//!
//! * **Determinism taint** (`contract-taint`) — the bit-exact contract
//!   is transitive: every fn *reachable* from a `CONTRACT: bit-exact`
//!   region must itself sit in a covered region.  A callee that is
//!   deliberately outside the contract (telemetry, error formatting)
//!   is marked at its definition with `// CONTRACT: bit-exact (leaf)`,
//!   which sanctions the call edge and stops the walk — the leaf's own
//!   callees are not visited.  Unmarked reachable fns are findings at
//!   their definition site, with the offending call path in the
//!   message.
//! * **Lock order** (`lock-order`) — nested `.lock()` acquisitions are
//!   collected into a static lock graph (labels are
//!   `module::path/receiver.field`).  Every observed ordering must be
//!   declared in the checked-in registry
//!   `rust/src/analysis/locks.toml` (`[[order]]` entries with `first`,
//!   `then`, and a mandatory `reason`); undeclared edges, cycles among
//!   declared-or-observed edges, and stale registry entries all fail
//!   the gate.
//! * **Blocking under lock** (`blocking-under-lock`) — no
//!   `recv`/`join`/`sleep`/file- or socket-I/O while a `MutexGuard` is
//!   live, including interprocedurally: a fn that blocks internally is
//!   a finding when called with a guard held.
//!
//! Exceptions go through `src/analysis/allow.toml`: narrowest possible
//! match, mandatory `reason`, and stale entries fail the build
//! (`unused-allow`) — the process is documented at the top of that
//! file.  Lock-order exceptions are *not* allowlisted; they are
//! declared orderings in `locks.toml`, so the registry stays the
//! single source of truth for the crate's lock hierarchy.  Findings
//! stream as reason-tagged JSONL (`lint-finding`, `lint-allowed`,
//! `lint-summary`) via [`telemetry::events::EventLog`], and CI
//! archives the report — plus the call/lock graph
//! (`GRAPH_report.jsonl`) — as artifacts.

pub mod analysis;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod distance;
pub mod error;
pub mod eval;
pub mod kernel;
pub mod model;
pub mod partition;
pub mod pipeline;
pub mod runtime;
pub mod server;
pub mod telemetry;
pub mod util;

pub use error::{Error, Result};
