//! # parsample
//!
//! A production-grade reproduction of **"A parallel sampling based
//! clustering"** (Sastry & Netti, 2014) as a three-layer
//! rust + JAX + Pallas system:
//!
//! * **L3 (this crate):** the paper's *host part* — dataset handling,
//!   feature scaling, the equal/unequal landmark partitioners, batching
//!   of sub-regions into fixed-shape device dispatches, a worker pool,
//!   the global clustering stage, a job server, CLI and telemetry —
//!   plus the traditional-k-means baseline every table compares against.
//! * **L2/L1 (python/, build-time only):** the *device part* — batched
//!   Lloyd iterations with a Pallas assignment kernel, AOT-lowered to
//!   HLO text that [`runtime`] loads and executes via PJRT.
//!
//! Quick start (see `examples/quickstart.rs`):
//!
//! ```no_run
//! use parsample::pipeline::{PipelineConfig, SubclusterPipeline};
//! use parsample::data::builtin;
//!
//! let data = builtin::iris();
//! let cfg = PipelineConfig::builder()
//!     .num_groups(6)
//!     .compression(6.0)
//!     .final_k(3)
//!     .build()
//!     .unwrap();
//! let result = SubclusterPipeline::new(cfg).run(&data).unwrap();
//! println!("inertia {}", result.inertia);
//! ```

pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod distance;
pub mod error;
pub mod eval;
pub mod kernel;
pub mod partition;
pub mod pipeline;
pub mod runtime;
pub mod server;
pub mod telemetry;
pub mod util;

pub use error::{Error, Result};
