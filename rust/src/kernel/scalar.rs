//! The original per-center scalar tile kernel, moved verbatim from the
//! engine.  Every distance is `|p|² − 2·p·c + |c|²` with all three
//! terms through [`crate::distance::dot`], clamped at 0, and centers
//! are scanned in increasing index under a strict `<` — the
//! bit-identical-argmin yardstick the parity suite pins down.
//!
//! CONTRACT: bit-exact — this file IS the yardstick; `parsample-lint`
//! forbids every nondeterminism source here.

use super::{TileKernel, TilePlan, POINT_CHUNK};
use crate::distance;

/// The scalar tile kernel (see module doc).
#[derive(Debug, Clone, Copy, Default)]
pub struct ScalarKernel;

impl TileKernel for ScalarKernel {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn plan<'a>(
        &self,
        centers: &'a [f32],
        cnorm: &'a [f32],
        _dims: usize,
        ctile: usize,
    ) -> Box<dyn TilePlan + 'a> {
        Box::new(ScalarPlan { centers, cnorm, ctile })
    }
}

/// Per-pass state of the scalar kernel: just borrows of the centers
/// and their norms — no layout transform.
struct ScalarPlan<'a> {
    centers: &'a [f32],
    cnorm: &'a [f32],
    ctile: usize,
}

impl TilePlan for ScalarPlan<'_> {
    /// The tiled inner sweep.  Point chunks stream against center
    /// tiles of `ctile` rows; the running (best, dist) per point
    /// carries across tiles, and because tiles are visited in
    /// increasing center order under a strict `<`, ties break to the
    /// lowest index exactly like the un-blocked scalar path.
    fn chunk_argmin(
        &self,
        points: &[f32],
        dims: usize,
        s: usize,
        cap: usize,
        pn: &[f32],
        best_i: &mut [u32; POINT_CHUNK],
        best_d: &mut [f32; POINT_CHUNK],
    ) {
        let k = self.cnorm.len();
        for i in 0..cap {
            best_i[i] = 0;
            best_d[i] = f32::INFINITY;
        }
        let mut t0 = 0usize;
        while t0 < k {
            let t1 = (t0 + self.ctile).min(k);
            let tile = &self.centers[t0 * dims..t1 * dims];
            let tnorm = &self.cnorm[t0..t1];
            for i in 0..cap {
                let p = &points[(s + i) * dims..(s + i + 1) * dims];
                let (mut bi, mut bd) = (best_i[i], best_d[i]);
                for (tc, cc) in tile.chunks_exact(dims).enumerate() {
                    let d = (pn[i] - 2.0 * distance::dot(p, cc) + tnorm[tc]).max(0.0);
                    if d < bd {
                        bd = d;
                        bi = (t0 + tc) as u32;
                    }
                }
                best_i[i] = bi;
                best_d[i] = bd;
            }
            t0 = t1;
        }
    }

    /// The gather sweep over Hamerly survivors, tracking second-best.
    /// Tiles are visited in the same increasing center order under the
    /// same strict `<`, so labels and best distances are bit-identical
    /// to the dense sweep.
    fn chunk_argmin2_gather(
        &self,
        points: &[f32],
        dims: usize,
        s: usize,
        surv: &[u32],
        pn: &[f32],
        best_i: &mut [u32; POINT_CHUNK],
        best_d: &mut [f32; POINT_CHUNK],
        second: &mut [f32; POINT_CHUNK],
    ) {
        let k = self.cnorm.len();
        let n = surv.len();
        for j in 0..n {
            best_i[j] = 0;
            best_d[j] = f32::INFINITY;
            second[j] = f32::INFINITY;
        }
        let mut t0 = 0usize;
        while t0 < k {
            let t1 = (t0 + self.ctile).min(k);
            let tile = &self.centers[t0 * dims..t1 * dims];
            let tnorm = &self.cnorm[t0..t1];
            for j in 0..n {
                let row = s + surv[j] as usize;
                let p = &points[row * dims..(row + 1) * dims];
                let (mut bi, mut bd, mut b2) = (best_i[j], best_d[j], second[j]);
                for (tc, cc) in tile.chunks_exact(dims).enumerate() {
                    let d =
                        (pn[surv[j] as usize] - 2.0 * distance::dot(p, cc) + tnorm[tc]).max(0.0);
                    if d < bd {
                        b2 = bd;
                        bd = d;
                        bi = (t0 + tc) as u32;
                    } else if d < b2 {
                        b2 = d;
                    }
                }
                best_i[j] = bi;
                best_d[j] = bd;
                second[j] = b2;
            }
            t0 = t1;
        }
    }

    fn dist1(&self, points: &[f32], dims: usize, i: usize, c: usize, pn_i: f32) -> f32 {
        super::norm_hoisted_dist(points, dims, i, self.centers, self.cnorm, c, pn_i)
    }
}
