//! Pluggable tile kernels — the innermost argmin sweep of the blocked
//! assignment engine as an extension point.
//!
//! CONTRACT: bit-exact — every kernel must reproduce the scalar
//! yardstick's labels and distances bit for bit (`parsample-lint`
//! forbids the nondeterminism sources listed in `crate`'s Invariants
//! section anywhere in this file).
//!
//! [`crate::cluster::engine`] owns blocking (point chunks × center
//! tiles), threading, and the Hamerly bound bookkeeping; everything
//! below a chunk — "given ≤ [`POINT_CHUNK`] points and the center
//! tiles, find each point's nearest (and second-nearest) center" — is a
//! [`TileKernel`].  Two implementations ship today:
//!
//! * [`ScalarKernel`] — the original per-center scalar sweep, moved
//!   here verbatim.  This is the semantic yardstick: every distance
//!   flows through [`crate::distance::dot`], ties break to the lowest
//!   index under a strict `<`, and the parity suite pins its output
//!   against the un-blocked scalar path bit for bit.
//! * [`WideKernel`] — an 8-lane kernel that packs each center tile
//!   into lane-major groups and sweeps one point against [`LANES`]
//!   centers per step (fixed-width lane arrays the compiler
//!   auto-vectorizes; on x86_64 an `is_x86_feature_detected!("avx2")`
//!   gated `target_feature` variant lets LLVM use 256-bit ops, with a
//!   portable fallback everywhere else).  Its per-lane dot product
//!   replays [`crate::distance::dot`]'s exact summation order (four
//!   accumulators, left-associated reduce, sequential tail) and lanes
//!   are reduced in increasing center order under the same strict `<`,
//!   so labels, distances, and second-best distances are bit-identical
//!   to [`ScalarKernel`] — the SIMD win comes from instruction-level
//!   parallelism across *centers*, not from reassociating any float
//!   sum.
//!
//! A kernel is used through a per-pass [`TilePlan`]: the engine hands
//! the kernel its centers once per sweep and the kernel may transform
//! the layout (the wide kernel packs lanes; a future device kernel
//! would upload the centers here) so the per-chunk calls do no setup
//! work at all.
//!
//! The [`KernelMode`] knob selects the kernel everywhere an engine is
//! built (`KMeansConfig`, `PipelineConfig`, the `pipeline.kernel`
//! config key, CLI `--kernel scalar|wide|auto`).  `Scalar` is the
//! default — the bit-identical-argmin contract stays anchored on the
//! original code path — and `Auto` picks `Wide` when the detected CPU
//! features (or the dimensionality) make it a clear win.

use std::sync::OnceLock;

pub mod scalar;
pub mod wide;

pub use scalar::ScalarKernel;
pub use wide::WideKernel;

/// Points held against one center tile before advancing to the next
/// tile.  64 points × (best, dist, |p|²) state fits comfortably in
/// registers + L1 alongside the tile itself.
pub const POINT_CHUNK: usize = 64;

/// Lane width of [`WideKernel`]: centers swept per SIMD step (8 × f32
/// = one AVX2 register; two NEON/SSE registers on narrower machines).
pub const LANES: usize = 8;

/// A per-pass execution plan built by [`TileKernel::plan`]: the
/// centers (and whatever derived layout the kernel wants) captured
/// once, then queried chunk by chunk.  Plans are shared read-only
/// across the engine's worker threads.
pub trait TilePlan: Send + Sync {
    /// Argmin over all centers for the `cap` points starting at row
    /// `s` (`cap` ≤ [`POINT_CHUNK`]), writing into the caller's
    /// chunk-state arrays.  `pn[i]` is the cached `dot(p, p)` of row
    /// `s + i`.  Resets `best_i`/`best_d` itself.  Centers are visited
    /// in increasing index under a strict `<`, so ties break to the
    /// lowest index.
    #[allow(clippy::too_many_arguments)]
    fn chunk_argmin(
        &self,
        points: &[f32],
        dims: usize,
        s: usize,
        cap: usize,
        pn: &[f32],
        best_i: &mut [u32; POINT_CHUNK],
        best_d: &mut [f32; POINT_CHUNK],
    );

    /// [`TilePlan::chunk_argmin`] for a scattered subset of one
    /// chunk's points, also tracking the second-best distance (the
    /// Hamerly lower-bound seed).  `surv[j]` are offsets within the
    /// chunk starting at row `s`; `pn[surv[j]]` is the cached
    /// `dot(p, p)` of row `s + surv[j]`; results land at position `j`
    /// of the output arrays.  Labels and distances must be
    /// bit-identical to what the dense sweep would produce for the
    /// same rows.
    #[allow(clippy::too_many_arguments)]
    fn chunk_argmin2_gather(
        &self,
        points: &[f32],
        dims: usize,
        s: usize,
        surv: &[u32],
        pn: &[f32],
        best_i: &mut [u32; POINT_CHUNK],
        best_d: &mut [f32; POINT_CHUNK],
        second: &mut [f32; POINT_CHUNK],
    );

    /// Squared distance from point row `i` to center `c`, evaluated
    /// with exactly the expression the dense sweep uses, so a
    /// bound-pruned point's carried distance is bit-identical to what
    /// the full k-sweep would have kept for it.  `pn_i` is the cached
    /// `dot(p, p)` of row `i`.
    fn dist1(&self, points: &[f32], dims: usize, i: usize, c: usize, pn_i: f32) -> f32;
}

/// A tile-kernel strategy.  Stateless; per-sweep state lives in the
/// [`TilePlan`] it builds.
pub trait TileKernel: Send + Sync {
    /// Short identifier for logs/benches.
    fn name(&self) -> &'static str;

    /// Build the per-pass plan for one set of centers.  `cnorm` holds
    /// the precomputed `|c|²` values (via [`crate::distance::dot`]),
    /// `ctile` is the engine's centers-per-tile blocking.
    fn plan<'a>(
        &self,
        centers: &'a [f32],
        cnorm: &'a [f32],
        dims: usize,
        ctile: usize,
    ) -> Box<dyn TilePlan + 'a>;
}

/// The one norm-hoisted single-distance expression behind every
/// [`TilePlan::dist1`]: `|p|² − 2·p·c + |c|²`, all through
/// [`crate::distance::dot`], clamped at 0.  Shared so the
/// bit-exactness contract (a pruned point's carried distance equals
/// what the dense sweep would have kept) lives in exactly one place.
#[inline]
pub(crate) fn norm_hoisted_dist(
    points: &[f32],
    dims: usize,
    i: usize,
    centers: &[f32],
    cnorm: &[f32],
    c: usize,
    pn_i: f32,
) -> f32 {
    let p = &points[i * dims..(i + 1) * dims];
    let cc = &centers[c * dims..(c + 1) * dims];
    (pn_i - 2.0 * crate::distance::dot(p, cc) + cnorm[c]).max(0.0)
}

/// The singleton [`ScalarKernel`].
pub static SCALAR: ScalarKernel = ScalarKernel;

/// The singleton [`WideKernel`].
pub static WIDE: WideKernel = WideKernel;

/// Which tile kernel the engine runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelMode {
    /// The original per-center scalar sweep — the default and the
    /// bit-identical yardstick.
    #[default]
    Scalar,
    /// The 8-lane packed kernel ([`WideKernel`]).
    Wide,
    /// Pick [`KernelMode::Wide`] when the detected CPU features (or
    /// the dimensionality) make it a clear win, else fall back to
    /// [`KernelMode::Scalar`].
    Auto,
}

impl KernelMode {
    pub fn parse(s: &str) -> crate::error::Result<Self> {
        match s {
            "scalar" => Ok(KernelMode::Scalar),
            "wide" | "simd" => Ok(KernelMode::Wide),
            "auto" => Ok(KernelMode::Auto),
            other => Err(crate::error::Error::Config(format!(
                "unknown kernel mode '{other}' (expected scalar|wide|auto)"
            ))),
        }
    }

    /// Canonical spelling, inverse of [`KernelMode::parse`] (model
    /// artifacts and the wire protocol serialize the mode as this).
    pub fn as_str(self) -> &'static str {
        match self {
            KernelMode::Scalar => "scalar",
            KernelMode::Wide => "wide",
            KernelMode::Auto => "auto",
        }
    }

    /// Resolve the mode to a concrete kernel for one sweep.  `dims`
    /// feeds the `Auto` heuristic.
    pub fn resolve(self, dims: usize) -> &'static dyn TileKernel {
        match self {
            KernelMode::Scalar => &SCALAR,
            KernelMode::Wide => &WIDE,
            KernelMode::Auto => {
                if wide_profitable(dims) {
                    &WIDE
                } else {
                    &SCALAR
                }
            }
        }
    }

    /// Process-wide default: `PARSAMPLE_KERNEL=scalar|wide|auto` when
    /// set (CI runs the whole test suite once per mode through this),
    /// else [`KernelMode::Scalar`].  Read once and cached.
    pub fn session_default() -> KernelMode {
        static MODE: OnceLock<KernelMode> = OnceLock::new();
        *MODE.get_or_init(|| match std::env::var("PARSAMPLE_KERNEL") {
            Ok(v) => KernelMode::parse(&v).unwrap_or_else(|e| {
                eprintln!("warning: ignoring PARSAMPLE_KERNEL: {e}");
                KernelMode::Scalar
            }),
            Err(_) => KernelMode::Scalar,
        })
    }
}

/// `Auto` heuristic: the wide kernel wins whenever the target has
/// ≥ 256-bit vectors (x86_64 with AVX2) or baseline 128-bit SIMD with
/// cheap lane ops (aarch64 NEON); on anything older it still wins once
/// the per-center dot is long enough to amortize the lane traffic.
fn wide_profitable(dims: usize) -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return true;
        }
    }
    if cfg!(target_arch = "aarch64") {
        return true;
    }
    dims >= 8
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_modes() {
        assert_eq!(KernelMode::parse("scalar").unwrap(), KernelMode::Scalar);
        assert_eq!(KernelMode::parse("wide").unwrap(), KernelMode::Wide);
        assert_eq!(KernelMode::parse("simd").unwrap(), KernelMode::Wide);
        assert_eq!(KernelMode::parse("auto").unwrap(), KernelMode::Auto);
        assert!(KernelMode::parse("gpu").is_err());
    }

    #[test]
    fn default_is_scalar() {
        // the bit-identical-argmin contract anchors on the scalar path
        assert_eq!(KernelMode::default(), KernelMode::Scalar);
    }

    #[test]
    fn resolve_fixed_modes() {
        assert_eq!(KernelMode::Scalar.resolve(16).name(), "scalar");
        assert_eq!(KernelMode::Wide.resolve(16).name(), "wide");
        // auto resolves to one of the two, whatever the host is
        let auto = KernelMode::Auto.resolve(16).name();
        assert!(auto == "scalar" || auto == "wide", "{auto}");
        // high dims always have enough work for the portable wide path
        assert_eq!(KernelMode::Auto.resolve(64).name(), "wide");
    }
}
