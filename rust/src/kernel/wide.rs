//! The 8-lane packed tile kernel.
//!
//! [`WideKernel`] gets its speed from instruction-level parallelism
//! across *centers*: [`WideKernel::plan`] repacks each center tile
//! into lane-major groups of [`LANES`] centers (`packed[g][j][l]` =
//! coordinate `j` of the group's lane-`l` center), so the inner sweep
//! broadcasts one point coordinate against 8 contiguous center
//! coordinates per step — the shape LLVM auto-vectorizes into full
//! vector multiply-adds.  On x86_64 the sweep additionally runs
//! through an `is_x86_feature_detected!("avx2")`-gated
//! `#[target_feature]` variant so those lane arrays become single
//! 256-bit registers; everywhere else the portable build vectorizes to
//! whatever the baseline ISA offers (SSE2, NEON).
//!
//! CONTRACT: bit-exact — the lane sweep must stay bit-identical to
//! [`super::ScalarKernel`]; `parsample-lint` forbids every
//! nondeterminism source in this file (and the Numerics paragraph
//! below is the reason reassociation is off the table).
//!
//! **Numerics.**  The per-lane dot product in [`dot_lanes`] replays
//! [`crate::distance::dot`]'s summation order exactly — four
//! accumulators over 4-coordinate blocks, a left-associated reduce,
//! then a sequential tail — and each lane's distance uses the same
//! `|p|² − 2·p·c + |c|²`-clamped-at-0 expression on the same cached
//! norms.  The lane reduction visits lanes in increasing center order
//! under a strict `<`, so the lowest-index tie rule is preserved.  The
//! result: labels, best distances, and second-best distances are
//! bit-identical to [`super::ScalarKernel`]'s (the kernel-parity suite
//! asserts this), and the engine's Hamerly bound margins — sized for
//! the worst-case f32 rounding of that shared expression — stay valid
//! unchanged.
//!
//! **Bounds pruning composes.**  The Hamerly survivor sweep arrives as
//! a scattered offset list; points are swept one at a time against
//! dense center lanes, so survivor compaction is free — every vector
//! lane does useful work no matter how many points were pruned — and
//! the second-best tracking the lower bound needs lives inside the
//! same lane reduction.
//!
//! Tail centers (tile size not a multiple of [`LANES`]) ride in padded
//! lanes with zero coordinates and `|c|² = +∞`: their distances are
//! `+∞`, which a strict `<` can never select.

use super::{TileKernel, TilePlan, LANES, POINT_CHUNK};

/// The 8-lane packed tile kernel (see module doc).
#[derive(Debug, Clone, Copy, Default)]
pub struct WideKernel;

impl TileKernel for WideKernel {
    fn name(&self) -> &'static str {
        "wide"
    }

    fn plan<'a>(
        &self,
        centers: &'a [f32],
        cnorm: &'a [f32],
        dims: usize,
        ctile: usize,
    ) -> Box<dyn TilePlan + 'a> {
        Box::new(WidePlan::build(centers, cnorm, dims, ctile))
    }
}

/// One lane-major center tile: `groups` groups of [`LANES`] centers
/// starting at center index `c0`, at `buf_off`/`tn_off` in the plan's
/// packed buffers.
struct TileSpan {
    c0: usize,
    groups: usize,
    buf_off: usize,
    tn_off: usize,
}

/// Per-pass state of the wide kernel: the centers repacked lane-major
/// per tile (plus the original borrows for [`TilePlan::dist1`]).
struct WidePlan<'a> {
    centers: &'a [f32],
    cnorm: &'a [f32],
    /// Lane-major center coordinates, `dims × LANES` floats per group.
    packed: Vec<f32>,
    /// Lane-major `|c|²` per group; padded lanes hold `+∞`.
    tn: Vec<f32>,
    tiles: Vec<TileSpan>,
    #[cfg(target_arch = "x86_64")]
    avx2: bool,
}

impl<'a> WidePlan<'a> {
    fn build(centers: &'a [f32], cnorm: &'a [f32], dims: usize, ctile: usize) -> WidePlan<'a> {
        let k = cnorm.len();
        let ctile = ctile.max(1);
        // every tile pads its last group up to LANES, so the exact
        // total is Σ ceil(count_t / LANES); ceil(k/LANES) + one group
        // per tile is a cheap upper bound that avoids mid-build growth
        let n_tiles = k.div_ceil(ctile);
        let max_groups = k.div_ceil(LANES) + n_tiles;
        let mut packed = Vec::with_capacity(max_groups * LANES * dims);
        let mut tn = Vec::with_capacity(max_groups * LANES);
        let mut tiles = Vec::with_capacity(n_tiles);
        let mut t0 = 0usize;
        while t0 < k {
            let t1 = (t0 + ctile).min(k);
            let count = t1 - t0;
            let groups = count.div_ceil(LANES);
            let buf_off = packed.len();
            let tn_off = tn.len();
            packed.resize(buf_off + groups * dims * LANES, 0.0);
            tn.resize(tn_off + groups * LANES, f32::INFINITY);
            for c in 0..count {
                let (g, l) = (c / LANES, c % LANES);
                let row = &centers[(t0 + c) * dims..(t0 + c + 1) * dims];
                let gb = buf_off + g * dims * LANES;
                for (j, &x) in row.iter().enumerate() {
                    packed[gb + j * LANES + l] = x;
                }
                tn[tn_off + g * LANES + l] = cnorm[t0 + c];
            }
            tiles.push(TileSpan { c0: t0, groups, buf_off, tn_off });
            t0 = t1;
        }
        WidePlan {
            centers,
            cnorm,
            packed,
            tn,
            tiles,
            #[cfg(target_arch = "x86_64")]
            avx2: is_x86_feature_detected!("avx2"),
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    // SAFETY: `#[target_feature(enable = "avx2")]` makes this fn
    // unsafe to call — executing it on a CPU without AVX2 is undefined
    // behaviour.  The body is plain safe Rust (no intrinsics, no raw
    // pointers): the attribute only licenses LLVM to emit 256-bit ops.
    // Callers must check `is_x86_feature_detected!("avx2")` first; the
    // only call site gates on the cached `WidePlan::avx2` flag.
    unsafe fn dense_avx2(
        &self,
        points: &[f32],
        dims: usize,
        s: usize,
        cap: usize,
        pn: &[f32],
        best_i: &mut [u32; POINT_CHUNK],
        best_d: &mut [f32; POINT_CHUNK],
    ) {
        self.dense_body(points, dims, s, cap, pn, best_i, best_d);
    }

    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    // SAFETY: same contract as [`WidePlan::dense_avx2`] — unsafe only
    // because of `#[target_feature(enable = "avx2")]`; the body is safe
    // Rust and the sole call site gates on `WidePlan::avx2`, which was
    // populated from `is_x86_feature_detected!("avx2")` at build time.
    unsafe fn gather_avx2(
        &self,
        points: &[f32],
        dims: usize,
        s: usize,
        surv: &[u32],
        pn: &[f32],
        best_i: &mut [u32; POINT_CHUNK],
        best_d: &mut [f32; POINT_CHUNK],
        second: &mut [f32; POINT_CHUNK],
    ) {
        self.gather_body(points, dims, s, surv, pn, best_i, best_d, second);
    }

    /// The dense sweep (portable body; compiled a second time under
    /// AVX2 via [`WidePlan::dense_avx2`]).
    #[inline(always)]
    #[allow(clippy::too_many_arguments)]
    fn dense_body(
        &self,
        points: &[f32],
        dims: usize,
        s: usize,
        cap: usize,
        pn: &[f32],
        best_i: &mut [u32; POINT_CHUNK],
        best_d: &mut [f32; POINT_CHUNK],
    ) {
        for i in 0..cap {
            best_i[i] = 0;
            best_d[i] = f32::INFINITY;
        }
        for tile in &self.tiles {
            for i in 0..cap {
                let p = &points[(s + i) * dims..(s + i + 1) * dims];
                let (mut bi, mut bd) = (best_i[i], best_d[i]);
                for g in 0..tile.groups {
                    let gb = tile.buf_off + g * dims * LANES;
                    let tot = dot_lanes(p, &self.packed[gb..gb + dims * LANES]);
                    let tb = tile.tn_off + g * LANES;
                    for l in 0..LANES {
                        let d = (pn[i] - 2.0 * tot[l] + self.tn[tb + l]).max(0.0);
                        if d < bd {
                            bd = d;
                            bi = (tile.c0 + g * LANES + l) as u32;
                        }
                    }
                }
                best_i[i] = bi;
                best_d[i] = bd;
            }
        }
    }

    /// The survivor gather sweep with second-best tracking (portable
    /// body; compiled a second time under AVX2 via
    /// [`WidePlan::gather_avx2`]).
    #[inline(always)]
    #[allow(clippy::too_many_arguments)]
    fn gather_body(
        &self,
        points: &[f32],
        dims: usize,
        s: usize,
        surv: &[u32],
        pn: &[f32],
        best_i: &mut [u32; POINT_CHUNK],
        best_d: &mut [f32; POINT_CHUNK],
        second: &mut [f32; POINT_CHUNK],
    ) {
        let n = surv.len();
        for j in 0..n {
            best_i[j] = 0;
            best_d[j] = f32::INFINITY;
            second[j] = f32::INFINITY;
        }
        for tile in &self.tiles {
            for j in 0..n {
                let row = s + surv[j] as usize;
                let p = &points[row * dims..(row + 1) * dims];
                let pn_j = pn[surv[j] as usize];
                let (mut bi, mut bd, mut b2) = (best_i[j], best_d[j], second[j]);
                for g in 0..tile.groups {
                    let gb = tile.buf_off + g * dims * LANES;
                    let tot = dot_lanes(p, &self.packed[gb..gb + dims * LANES]);
                    let tb = tile.tn_off + g * LANES;
                    for l in 0..LANES {
                        let d = (pn_j - 2.0 * tot[l] + self.tn[tb + l]).max(0.0);
                        if d < bd {
                            b2 = bd;
                            bd = d;
                            bi = (tile.c0 + g * LANES + l) as u32;
                        } else if d < b2 {
                            b2 = d;
                        }
                    }
                }
                best_i[j] = bi;
                best_d[j] = bd;
                second[j] = b2;
            }
        }
    }
}

impl TilePlan for WidePlan<'_> {
    fn chunk_argmin(
        &self,
        points: &[f32],
        dims: usize,
        s: usize,
        cap: usize,
        pn: &[f32],
        best_i: &mut [u32; POINT_CHUNK],
        best_d: &mut [f32; POINT_CHUNK],
    ) {
        #[cfg(target_arch = "x86_64")]
        if self.avx2 {
            // SAFETY: avx2 presence was verified at plan build time.
            unsafe { self.dense_avx2(points, dims, s, cap, pn, best_i, best_d) };
            return;
        }
        self.dense_body(points, dims, s, cap, pn, best_i, best_d);
    }

    fn chunk_argmin2_gather(
        &self,
        points: &[f32],
        dims: usize,
        s: usize,
        surv: &[u32],
        pn: &[f32],
        best_i: &mut [u32; POINT_CHUNK],
        best_d: &mut [f32; POINT_CHUNK],
        second: &mut [f32; POINT_CHUNK],
    ) {
        #[cfg(target_arch = "x86_64")]
        if self.avx2 {
            // SAFETY: avx2 presence was verified at plan build time.
            unsafe { self.gather_avx2(points, dims, s, surv, pn, best_i, best_d, second) };
            return;
        }
        self.gather_body(points, dims, s, surv, pn, best_i, best_d, second);
    }

    fn dist1(&self, points: &[f32], dims: usize, i: usize, c: usize, pn_i: f32) -> f32 {
        // the packed lane dot replays distance::dot's summation order
        // exactly, so the shared scalar expression reproduces the
        // dense sweep's value bit for bit
        super::norm_hoisted_dist(points, dims, i, self.centers, self.cnorm, c, pn_i)
    }
}

/// Dot products of one point against [`LANES`] packed centers
/// (`block[j * LANES + l]` = coordinate `j` of the lane-`l` center).
///
/// Each lane replays [`crate::distance::dot`]'s float summation order
/// exactly: four accumulators striped over 4-coordinate blocks, the
/// left-associated reduce `((a0 + a1) + a2) + a3`, then the remaining
/// coordinates folded sequentially.  Keeping that order is what makes
/// the wide kernel bit-identical to the scalar one — do not
/// reassociate it.
#[inline(always)]
fn dot_lanes(p: &[f32], block: &[f32]) -> [f32; LANES] {
    let dims = p.len();
    let mut acc = [[0.0f32; LANES]; 4];
    let chunks = dims / 4;
    for c in 0..chunks {
        let jb = c * 4;
        for jj in 0..4 {
            let pj = p[jb + jj];
            let rb = (jb + jj) * LANES;
            for l in 0..LANES {
                acc[jj][l] += pj * block[rb + l];
            }
        }
    }
    let mut tot = [0.0f32; LANES];
    for l in 0..LANES {
        tot[l] = ((acc[0][l] + acc[1][l]) + acc[2][l]) + acc[3][l];
    }
    for j in chunks * 4..dims {
        let pj = p[j];
        let rb = j * LANES;
        for l in 0..LANES {
            tot[l] += pj * block[rb + l];
        }
    }
    tot
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distance::{self, center_norms};
    use crate::kernel::{ScalarKernel, SCALAR, WIDE};
    use crate::util::rng::Pcg32;

    fn cloud(n: usize, dims: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::seeded(seed);
        (0..n * dims).map(|_| rng.uniform(-4.0, 4.0)).collect()
    }

    /// Pack `lanes` center rows and check [`dot_lanes`] against
    /// [`distance::dot`] bit for bit, per lane, across dims including
    /// every 4-block tail shape.
    #[test]
    fn dot_lanes_bit_matches_distance_dot() {
        for dims in [1usize, 2, 3, 4, 5, 7, 8, 9, 12, 16, 17, 31, 32, 33] {
            let p = cloud(1, dims, dims as u64);
            let centers = cloud(LANES, dims, 100 + dims as u64);
            let mut block = vec![0.0f32; dims * LANES];
            for l in 0..LANES {
                for j in 0..dims {
                    block[j * LANES + l] = centers[l * dims + j];
                }
            }
            let tot = dot_lanes(&p, &block);
            for l in 0..LANES {
                let want = distance::dot(&p, &centers[l * dims..(l + 1) * dims]);
                assert_eq!(
                    tot[l].to_bits(),
                    want.to_bits(),
                    "dims={dims} lane={l}: {} vs {want}",
                    tot[l]
                );
            }
        }
    }

    #[test]
    fn zero_dims_dot_is_positive_zero() {
        // dims = 0 never happens in the engine, but the fold must not
        // produce -0.0 from the empty reduce (distance::dot returns +0)
        let tot = dot_lanes(&[], &[]);
        assert_eq!(tot, [0.0f32; LANES]);
        assert!(tot.iter().all(|t| t.to_bits() == 0));
    }

    /// Dense chunk sweep: wide plan output is bit-identical to the
    /// scalar plan on random data, including k not a multiple of the
    /// lane width (padded lanes must stay inert).
    #[test]
    fn dense_chunk_bit_matches_scalar_plan() {
        use crate::kernel::TileKernel;
        for dims in [1usize, 3, 5, 8, 9, 17] {
            for k in [1usize, 2, 7, 8, 9, 13, 24] {
                let m = POINT_CHUNK + 11; // one full chunk + a short one
                let pts = cloud(m, dims, 7 + dims as u64);
                let centers = cloud(k, dims, 900 + k as u64);
                let cnorm = center_norms(&centers, dims);
                let pn: Vec<f32> = pts.chunks_exact(dims).map(|p| distance::dot(p, p)).collect();
                let sp = SCALAR.plan(&centers, &cnorm, dims, 5);
                let wp = WIDE.plan(&centers, &cnorm, dims, 5);
                let mut s = 0usize;
                while s < m {
                    let cap = POINT_CHUNK.min(m - s);
                    let (mut si, mut sd) = ([0u32; POINT_CHUNK], [0.0f32; POINT_CHUNK]);
                    let (mut wi, mut wd) = ([0u32; POINT_CHUNK], [0.0f32; POINT_CHUNK]);
                    sp.chunk_argmin(&pts, dims, s, cap, &pn[s..s + cap], &mut si, &mut sd);
                    wp.chunk_argmin(&pts, dims, s, cap, &pn[s..s + cap], &mut wi, &mut wd);
                    assert_eq!(si[..cap], wi[..cap], "dims={dims} k={k} s={s}");
                    for i in 0..cap {
                        assert_eq!(
                            sd[i].to_bits(),
                            wd[i].to_bits(),
                            "dims={dims} k={k} s={s} i={i}"
                        );
                    }
                    s += cap;
                }
            }
        }
    }

    /// Gather sweep over a scattered survivor subset: wide output
    /// (including second-best) is bit-identical to scalar, and both
    /// agree with their own dense sweep on the surviving rows.
    #[test]
    fn gather_chunk_bit_matches_scalar_plan() {
        use crate::kernel::TileKernel;
        let (dims, k, m) = (9usize, 13usize, 40usize);
        let pts = cloud(m, dims, 5);
        let centers = cloud(k, dims, 55);
        let cnorm = center_norms(&centers, dims);
        let pn: Vec<f32> = pts.chunks_exact(dims).map(|p| distance::dot(p, p)).collect();
        let sp = ScalarKernel.plan(&centers, &cnorm, dims, 4);
        let wp = WideKernel.plan(&centers, &cnorm, dims, 4);
        // every 3rd point survives — a sparse scatter like a >60% skip
        let surv: Vec<u32> = (0..m as u32).step_by(3).collect();
        let mut si = [0u32; POINT_CHUNK];
        let mut sd = [0.0f32; POINT_CHUNK];
        let mut s2 = [0.0f32; POINT_CHUNK];
        let mut wi = [0u32; POINT_CHUNK];
        let mut wd = [0.0f32; POINT_CHUNK];
        let mut w2 = [0.0f32; POINT_CHUNK];
        sp.chunk_argmin2_gather(&pts, dims, 0, &surv, &pn, &mut si, &mut sd, &mut s2);
        wp.chunk_argmin2_gather(&pts, dims, 0, &surv, &pn, &mut wi, &mut wd, &mut w2);
        for j in 0..surv.len() {
            assert_eq!(si[j], wi[j], "j={j}");
            assert_eq!(sd[j].to_bits(), wd[j].to_bits(), "j={j}");
            assert_eq!(s2[j].to_bits(), w2[j].to_bits(), "j={j}");
        }
        // dist1 must reproduce the dense value for the winning center
        for (j, &off) in surv.iter().enumerate() {
            let d = wp.dist1(&pts, dims, off as usize, wi[j] as usize, pn[off as usize]);
            assert_eq!(d.to_bits(), wd[j].to_bits(), "j={j}");
        }
    }

    #[test]
    fn duplicate_centers_tie_to_lowest_lane() {
        use crate::kernel::TileKernel;
        // 19 identical centers span two groups and two tiles (ctile 10):
        // the winner must always be lane/center 0
        let dims = 3;
        let one = cloud(1, dims, 1);
        let centers: Vec<f32> = (0..19).flat_map(|_| one.clone()).collect();
        let cnorm = center_norms(&centers, dims);
        let pts = cloud(30, dims, 2);
        let pn: Vec<f32> = pts.chunks_exact(dims).map(|p| distance::dot(p, p)).collect();
        let wp = WideKernel.plan(&centers, &cnorm, dims, 10);
        let mut bi = [0u32; POINT_CHUNK];
        let mut bd = [0.0f32; POINT_CHUNK];
        wp.chunk_argmin(&pts, dims, 0, 30, &pn, &mut bi, &mut bd);
        assert!(bi[..30].iter().all(|&l| l == 0), "{:?}", &bi[..30]);
    }
}
