//! Bench: seeding wall time and final quality — engine-parallel
//! k-means‖ vs classic k-means++ vs uniform random, at a
//! pipeline-regime center count (the shape where the classic seeder's
//! serial O(k·M·D) sweep is the wall).
//!
//! Profiles (points / clusters / dims):
//!   PARSAMPLE_BENCH_SMOKE=1  →   4k /  64 /  8   (CI rot-guard)
//!   default                  →  60k / 128 / 16
//!   PARSAMPLE_BENCH_FULL=1   → 200k / 256 / 16
//!
//! Before timing anything, asserts the k-means‖ reproducibility
//! contract: bit-identical centers across worker counts × tile
//! kernels.  Then times each seeder, runs a fixed Lloyd refinement
//! from each seed set, and emits wall times plus final inertias into
//! `BENCH_init.json` — the quality claim is that ‖ seeds land within
//! noise of ++ while the seeding itself parallelises.

use parsample::cluster::engine::{BoundsMode, Engine};
use parsample::cluster::init::{initial_centers_with, InitMethod};
use parsample::cluster::init_parallel::sampling_rounds;
use parsample::cluster::{EngineOpts, KernelMode};
use parsample::data::synthetic::{make_blobs, BlobSpec};
use parsample::util::benchkit::{print_table, Bench};
use parsample::util::json::Json;

fn main() {
    let smoke = std::env::var("PARSAMPLE_BENCH_SMOKE").is_ok();
    let full = std::env::var("PARSAMPLE_BENCH_FULL").is_ok();
    let (m, k, d) = if smoke {
        (4_000usize, 64usize, 8usize)
    } else if full {
        (200_000, 256, 16)
    } else {
        (60_000, 128, 16)
    };
    let refine_iters = 10;
    let workers = 4;
    let seed = 7;

    let ds = make_blobs(&BlobSpec {
        num_points: m,
        num_clusters: k,
        dims: d,
        std: 0.05,
        extent: 10.0,
        seed: 42,
    })
    .expect("blob generation");
    let points = ds.as_slice();

    let seed_with = |method: InitMethod, opts: EngineOpts| {
        initial_centers_with(points, d, k, method, seed, opts).expect("seeding")
    };
    let opts = |workers, kernel| EngineOpts { workers, bounds: BoundsMode::Off, kernel };

    // reproducibility gate before timing anything: k-means‖ must be
    // bit-identical across worker counts and tile kernels
    let baseline = seed_with(InitMethod::KMeansParallel, opts(1, KernelMode::Scalar));
    for w in [1usize, workers] {
        for kernel in [KernelMode::Scalar, KernelMode::Wide] {
            let got = seed_with(InitMethod::KMeansParallel, opts(w, kernel));
            assert_eq!(
                baseline, got,
                "k-means|| drifted at workers={w} kernel={kernel:?}"
            );
        }
    }

    let timed = opts(workers, KernelMode::session_default());
    let bench = if smoke { Bench::new(0, 2) } else { Bench::new(1, 5) };
    let s_par = bench.run("seed/kmeans||", || {
        seed_with(InitMethod::KMeansParallel, timed)
    });
    let s_pp = bench.run("seed/kmeans++", || {
        seed_with(InitMethod::KMeansPlusPlus, timed)
    });
    let s_rand = bench.run("seed/random", || seed_with(InitMethod::Random, timed));
    let speedup = s_pp.mean_ms() / s_par.mean_ms();

    // quality: fixed Lloyd refinement from each seed set — final
    // inertia is the figure of merit (‖ should land within noise of
    // ++, both well under random)
    let engine = Engine::new(workers);
    let refine = |method: InitMethod| {
        let init = seed_with(method, timed);
        engine
            .lloyd_loop(points, d, init, refine_iters, 0.0, BoundsMode::Hamerly)
            .inertia
    };
    let in_par = refine(InitMethod::KMeansParallel);
    let in_pp = refine(InitMethod::KMeansPlusPlus);
    let in_rand = refine(InitMethod::Random);

    print_table(
        &format!(
            "Seeding quality — {refine_iters}-iter Lloyd refinement (m={m}, k={k}, d={d}, rounds={})",
            sampling_rounds(m)
        ),
        &["method", "seed ms", "vs ++", "final inertia"],
        &[
            vec![
                "kmeans||".into(),
                format!("{:.3}", s_par.mean_ms()),
                format!("{speedup:.2}x"),
                format!("{in_par:.4e}"),
            ],
            vec![
                "kmeans++".into(),
                format!("{:.3}", s_pp.mean_ms()),
                "1.00x".into(),
                format!("{in_pp:.4e}"),
            ],
            vec![
                "random".into(),
                format!("{:.3}", s_rand.mean_ms()),
                "-".into(),
                format!("{in_rand:.4e}"),
            ],
        ],
    );

    let json = Json::obj(vec![
        ("bench", Json::str("init_quality")),
        ("m", Json::num(m as f64)),
        ("k", Json::num(k as f64)),
        ("d", Json::num(d as f64)),
        ("workers", Json::num(workers as f64)),
        ("rounds", Json::num(sampling_rounds(m) as f64)),
        ("refine_iters", Json::num(refine_iters as f64)),
        ("parallel_mean_ms", Json::num(s_par.mean_ms())),
        ("plusplus_mean_ms", Json::num(s_pp.mean_ms())),
        ("random_mean_ms", Json::num(s_rand.mean_ms())),
        ("seeding_speedup_vs_plusplus", Json::num(speedup)),
        ("inertia_parallel", Json::num(in_par)),
        ("inertia_plusplus", Json::num(in_pp)),
        ("inertia_random", Json::num(in_rand)),
        ("inertia_ratio_parallel_over_plusplus", Json::num(in_par / in_pp)),
    ]);
    let out = "BENCH_init.json";
    match std::fs::write(out, json.to_string()) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
