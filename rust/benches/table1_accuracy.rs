//! Bench: Table 1 — accuracy + wall time on Iris and Seeds(sim).
//!
//! Regenerates the paper's accuracy table (see also
//! examples/iris_accuracy.rs) and times each method; the accuracy
//! numbers are printed alongside so the bench output alone reproduces
//! the table.  Run: `cargo bench --bench table1_accuracy`

use parsample::data::{builtin, Dataset};
use parsample::eval;
use parsample::partition::Scheme;
use parsample::pipeline::{traditional_kmeans, PipelineConfig, SubclusterPipeline};
use parsample::util::benchkit::{print_table, Bench};

fn pipeline_labels(data: &Dataset, scheme: Scheme) -> Vec<u32> {
    let cfg = PipelineConfig::builder()
        .scheme(scheme)
        .num_groups(6)
        .compression(6.0)
        .final_k(3)
        .weighted_global(true)
        .build()
        .expect("pipeline config");
    SubclusterPipeline::new(cfg).run(data).expect("pipeline run").labels
}

fn main() {
    let bench = Bench::new(1, 10);
    let mut rows = Vec::new();
    for (name, data, paper) in [
        ("iris", builtin::iris(), [133u64, 138, 138]),
        ("seeds", builtin::seeds_sim(0), [187, 191, 191]),
    ] {
        let truth = data.labels().expect("ground-truth labels").to_vec();
        let m = data.len();

        let stats = bench.run(&format!("{name}/standard_kmeans"), || {
            traditional_kmeans(&data, 3, 100, 0).expect("kmeans")
        });
        let labels = traditional_kmeans(&data, 3, 100, 0).expect("kmeans").labels;
        rows.push(vec![
            name.into(),
            "standard".into(),
            format!("{}/{m}", eval::correct_count(&labels, &truth).expect("eval")),
            format!("{}", paper[0]),
            format!("{:.3}", stats.mean_ms()),
        ]);

        for (label, scheme, pc) in [
            ("equal", Scheme::Equal, paper[1]),
            ("unequal", Scheme::Unequal, paper[2]),
        ] {
            let stats = bench.run(&format!("{name}/{label}_pipeline"), || {
                pipeline_labels(&data, scheme)
            });
            let labels = pipeline_labels(&data, scheme);
            rows.push(vec![
                name.into(),
                label.into(),
                format!("{}/{m}", eval::correct_count(&labels, &truth).expect("eval")),
                format!("{pc}"),
                format!("{:.3}", stats.mean_ms()),
            ]);
        }
    }
    print_table(
        "Table 1 — accuracy (measured vs paper) and time",
        &["dataset", "method", "correct", "paper", "mean ms"],
        &rows,
    );
}
