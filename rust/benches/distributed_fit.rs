//! Bench: the distributed sharded fit vs single-node, and under fire.
//!
//! Four scenarios over the same dataset:
//!
//! * **local**    — the in-process thread-pool local stage (baseline);
//! * **1 worker** — every group round-trips through one remote
//!   `serve` process (pure wire overhead);
//! * **2 workers** — the paper's fan-out across two processes;
//! * **2 workers, one killed at 50%** — a worker is shut down halfway
//!   through the expected fit: the pool retries, quarantines, and
//!   finishes on the survivor (fault-tolerance overhead).
//!
//! Every distributed run is asserted **bit-identical** to the local
//! fit before its time is recorded — wall time is the only thing
//! allowed to change.  Results go to `BENCH_dist.json`.
//!
//! Profiles (points / clusters / dims):
//!   PARSAMPLE_BENCH_SMOKE=1  →   6k / 8 / 8   (CI rot-guard)
//!   default                  →  60k / 16 / 8
//!   PARSAMPLE_BENCH_FULL=1   → 150k / 32 / 8

use std::time::{Duration, Instant};

use parsample::coordinator::{RemoteConfig, SchedulerConfig};
use parsample::data::synthetic::{make_blobs, BlobSpec};
use parsample::data::Dataset;
use parsample::pipeline::{PipelineConfig, PipelineResult, SubclusterPipeline};
use parsample::server::Server;
use parsample::util::benchkit::{black_box, print_table};
use parsample::util::json::Json;

fn pipeline_cfg(k: usize, remote: Option<RemoteConfig>) -> PipelineConfig {
    let mut b = PipelineConfig::builder()
        .final_k(k)
        .num_groups(8)
        .compression(5.0)
        .seed(0);
    if let Some(r) = remote {
        b = b.remote(r);
    }
    b.build().expect("pipeline config")
}

fn remote_cfg(workers: Vec<String>) -> RemoteConfig {
    RemoteConfig {
        workers,
        connect_timeout: Duration::from_millis(500),
        read_timeout: Duration::from_secs(30),
        max_attempts: 3,
        backoff_base: Duration::from_millis(10),
        backoff_cap: Duration::from_millis(100),
        quarantine_after: 2,
        probe_interval: Duration::from_millis(100),
        ..Default::default()
    }
}

fn start_worker() -> Server {
    Server::start("127.0.0.1:0", SchedulerConfig::default()).expect("worker start")
}

fn assert_parity(local: &PipelineResult, dist: &PipelineResult, what: &str) {
    assert_eq!(local.labels, dist.labels, "{what}: labels diverge");
    assert_eq!(local.centers, dist.centers, "{what}: centers diverge");
    assert_eq!(
        local.inertia.to_bits(),
        dist.inertia.to_bits(),
        "{what}: inertia diverges"
    );
}

/// Time one distributed fit against `workers` fresh servers, parity-
/// gated; `kill_after` shuts one worker down mid-fit.
fn timed_fit(
    data: &Dataset,
    k: usize,
    reference: &PipelineResult,
    workers: usize,
    kill_after: Option<Duration>,
    what: &str,
) -> f64 {
    let mut fleet: Vec<Server> = (0..workers).map(|_| start_worker()).collect();
    let addrs: Vec<String> = fleet.iter().map(|s| format!("{}", s.addr())).collect();
    let pipeline = SubclusterPipeline::new(pipeline_cfg(k, Some(remote_cfg(addrs))));
    let killer = kill_after.map(|after| {
        let mut victim = fleet.pop().expect("fleet has a victim");
        std::thread::spawn(move || {
            std::thread::sleep(after);
            victim.shutdown();
        })
    });
    let t0 = Instant::now();
    let r = pipeline.run(data).expect("distributed fit");
    let ms = t0.elapsed().as_secs_f64() * 1e3;
    assert_parity(reference, &r, what);
    black_box(r);
    if let Some(h) = killer {
        h.join().expect("killer thread");
    }
    for mut s in fleet {
        s.shutdown();
    }
    ms
}

fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len() as f64
}

fn main() {
    let smoke = std::env::var("PARSAMPLE_BENCH_SMOKE").is_ok();
    let full = std::env::var("PARSAMPLE_BENCH_FULL").is_ok();
    let (m, k) = if smoke {
        (6_000usize, 8usize)
    } else if full {
        (150_000, 32)
    } else {
        (60_000, 16)
    };
    let iters = if smoke { 2 } else { 4 };

    let data = make_blobs(&BlobSpec {
        num_points: m,
        num_clusters: k,
        dims: 8,
        std: 0.05,
        extent: 10.0,
        seed: 42,
    })
    .expect("blob generation");

    // single-node reference: the bits every scenario must reproduce
    let local_pipeline = SubclusterPipeline::new(pipeline_cfg(k, None));
    let reference = local_pipeline.run(&data).expect("local fit");
    let t_local: Vec<f64> = (0..iters)
        .map(|_| {
            let t0 = Instant::now();
            black_box(local_pipeline.run(&data).expect("local fit"));
            t0.elapsed().as_secs_f64() * 1e3
        })
        .collect();

    let t_w1: Vec<f64> = (0..iters)
        .map(|_| timed_fit(&data, k, &reference, 1, None, "1 worker"))
        .collect();
    let t_w2: Vec<f64> = (0..iters)
        .map(|_| timed_fit(&data, k, &reference, 2, None, "2 workers"))
        .collect();
    // kill one of two workers halfway through the healthy 2-worker time
    let kill_at = Duration::from_secs_f64(mean(&t_w2) / 2.0 / 1e3);
    let t_kill: Vec<f64> = (0..iters)
        .map(|_| timed_fit(&data, k, &reference, 2, Some(kill_at), "2 workers, one killed"))
        .collect();

    let rows: Vec<Vec<String>> = [
        ("local", &t_local),
        ("1 worker", &t_w1),
        ("2 workers", &t_w2),
        ("2 workers, one killed @50%", &t_kill),
    ]
    .iter()
    .map(|(name, ts)| {
        vec![
            name.to_string(),
            format!("{:.1}", mean(ts)),
            format!("{:.2}x", mean(ts) / mean(&t_local)),
        ]
    })
    .collect();
    print_table(
        &format!("distributed fit (m={m}, k={k}, d=8, groups=8, bit-identical everywhere)"),
        &["scenario", "mean ms", "vs local"],
        &rows,
    );

    let json = Json::obj(vec![
        ("bench", Json::str("distributed_fit")),
        ("m", Json::num(m as f64)),
        ("k", Json::num(k as f64)),
        ("d", Json::num(8.0)),
        ("groups", Json::num(8.0)),
        ("local_mean_ms", Json::num(mean(&t_local))),
        ("w1_mean_ms", Json::num(mean(&t_w1))),
        ("w2_mean_ms", Json::num(mean(&t_w2))),
        ("w2_kill_mean_ms", Json::num(mean(&t_kill))),
    ]);
    let out = "BENCH_dist.json";
    match std::fs::write(out, json.to_string()) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
