//! Bench: blocked multi-threaded assignment engine vs the scalar
//! assign path (per-point `nearest_sq_with_norms` + sequential
//! accumulate), on the global-stage shape the tentpole targets.
//!
//! Default is a quick profile (n=50k); the issue's reference shape
//! (n=200k, k=256, d=32) runs with:
//!   PARSAMPLE_BENCH_FULL=1 cargo bench --bench engine_scaling
//!
//! Emits `BENCH_engine.json` next to the CWD so the speedup lands in
//! the perf trajectory (target: ≥4x on 8 cores, ≥2x at 4 workers).

use parsample::cluster::engine::{serial_reference, Engine};
use parsample::util::benchkit::{print_table, Bench};
use parsample::util::json::Json;
use parsample::util::rng::Pcg32;

fn main() {
    let full = std::env::var("PARSAMPLE_BENCH_FULL").is_ok();
    let (n, k, d) = if full { (200_000usize, 256usize, 32usize) } else { (50_000, 256, 32) };

    let mut rng = Pcg32::seeded(42);
    let points: Vec<f32> = (0..n * d).map(|_| rng.uniform(-10.0, 10.0)).collect();
    // FirstK-style centers: the first k points
    let centers: Vec<f32> = points[..k * d].to_vec();

    // correctness gate before timing anything
    let reference = serial_reference(&points, d, &centers);
    let engine_labels = Engine::new(8).assign_only(&points, d, &centers);
    assert_eq!(reference.labels, engine_labels, "engine/scalar label mismatch");

    let bench = Bench::new(1, 5);
    let mut rows = Vec::new();
    let mut results: Vec<(String, usize, f64)> = Vec::new();

    let scalar = bench.run("scalar/serial_reference", || serial_reference(&points, d, &centers));
    results.push(("scalar".into(), 1, scalar.mean_ms()));

    for &workers in &[1usize, 2, 4, 8] {
        let engine = Engine::new(workers);
        let s = bench.run(&format!("engine/workers={workers}"), || {
            engine.assign_accumulate(&points, d, &centers)
        });
        results.push(("engine".into(), workers, s.mean_ms()));
    }

    for (path, workers, ms) in &results {
        rows.push(vec![
            path.clone(),
            format!("{workers}"),
            format!("{ms:.3}"),
            format!("{:.2}x", scalar.mean_ms() / ms),
        ]);
    }
    print_table(
        &format!("Engine scaling — fused assign+accumulate (n={n}, k={k}, d={d})"),
        &["path", "workers", "mean ms", "speedup vs scalar"],
        &rows,
    );

    let speedup_at = |w: usize| -> f64 {
        results
            .iter()
            .find(|(p, rw, _)| p == "engine" && *rw == w)
            .map(|(_, _, ms)| scalar.mean_ms() / ms)
            .unwrap_or(0.0)
    };
    let json = Json::obj(vec![
        ("bench", Json::str("engine_scaling")),
        ("n", Json::num(n as f64)),
        ("k", Json::num(k as f64)),
        ("d", Json::num(d as f64)),
        (
            "rows",
            Json::Arr(
                results
                    .iter()
                    .map(|(path, workers, ms)| {
                        Json::obj(vec![
                            ("path", Json::str(path.clone())),
                            ("workers", Json::num(*workers as f64)),
                            ("mean_ms", Json::num(*ms)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("speedup_2_workers", Json::num(speedup_at(2))),
        ("speedup_4_workers", Json::num(speedup_at(4))),
        ("speedup_8_workers", Json::num(speedup_at(8))),
    ]);
    let out = "BENCH_engine.json";
    match std::fs::write(out, json.to_string()) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
