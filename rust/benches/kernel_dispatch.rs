//! Bench: runtime micro-benchmarks — PJRT dispatch vs the native mirror
//! per AOT bucket, plus compile (warm-up) cost.
//!
//! Answers how much of the request path is device compute vs
//! coordinator overhead (see ROADMAP.md "Real PJRT execution" and the
//! per-PR perf notes in CHANGES.md).  Skips gracefully when artifacts/
//! has not been built.

use parsample::runtime::{Backend, DeviceBatch, NativeBackend, PjrtBackend};
use parsample::util::benchkit::{print_table, Bench};
use parsample::util::rng::Pcg32;

fn bucket_batch(spec: &parsample::runtime::BucketSpec, fill: f64, seed: u64) -> DeviceBatch {
    let (b, n, d, k) = (spec.b, spec.n, spec.d, spec.k);
    let real_n = ((n as f64) * fill) as usize;
    let real_k = (real_n / 5).max(1).min(k);
    let mut rng = Pcg32::seeded(seed);
    let mut points = vec![0.0f32; b * n * d];
    let mut weights = vec![0.0f32; b * n];
    let mut init = vec![1e12f32; b * k * d];
    for slot in 0..b {
        for i in 0..real_n {
            for j in 0..d {
                points[slot * n * d + i * d + j] = rng.uniform(0.0, 1.0);
            }
            weights[slot * n + i] = 1.0;
        }
        for c in 0..real_k {
            for j in 0..d {
                init[slot * k * d + c * d + j] = points[slot * n * d + c * d + j];
            }
        }
    }
    DeviceBatch { b, n, d, k, iters: spec.iters, points, weights, init }
}

fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping kernel_dispatch: run `make artifacts` first");
        return;
    }
    let pjrt = PjrtBackend::load(&dir).expect("load artifacts");
    let native = NativeBackend::new(parsample::util::threadpool::default_workers());
    let bench = Bench::new(1, 5);
    let mut rows = Vec::new();

    for spec in &pjrt.manifest().buckets.clone() {
        // skip the giant global bucket in the default bench profile
        if spec.n > 20_000 && std::env::var("PARSAMPLE_BENCH_FULL").is_err() {
            continue;
        }
        let batch = bucket_batch(spec, 0.75, 3);

        // compile cost (one-time per process)
        let t0 = std::time::Instant::now();
        pjrt.warm(&spec.name).expect("warm");
        let compile_ms = t0.elapsed().as_secs_f64() * 1e3;

        let p = bench.run(&format!("pjrt/{}", spec.name), || {
            pjrt.run_in_bucket(&spec.name, &batch).expect("device batch")
        });
        let nv = bench.run(&format!("native/{}", spec.name), || {
            native.run_batch(&batch).expect("native batch")
        });
        rows.push(vec![
            spec.name.clone(),
            format!("{}x{}x{}x{}", spec.b, spec.n, spec.d, spec.k),
            format!("{compile_ms:.0}"),
            format!("{:.2}", p.mean_ms()),
            format!("{:.2}", nv.mean_ms()),
            format!("{:.2}x", p.mean_ms() / nv.mean_ms()),
        ]);
    }
    print_table(
        "Runtime dispatch: PJRT (interpret-mode pallas) vs native mirror",
        &["bucket", "BxNxDxK", "compile ms", "pjrt ms", "native ms", "pjrt/native"],
        &rows,
    );
}
