//! Bench: scalar vs wide tile kernel on the single-thread Lloyd loop —
//! the instruction-level-parallelism half of the speedup story (the
//! thread-level half is `benches/engine_scaling.rs`, the pruning half
//! `benches/hamerly_pruning.rs`).
//!
//! Profiles (points / clusters / dims / iters):
//!   PARSAMPLE_BENCH_SMOKE=1  →  2k / 64 / 8 / 15   (CI rot-guard)
//!   default                  → 40k / 96 / 16 / 30  (the acceptance shape)
//!   PARSAMPLE_BENCH_FULL=1   → 120k / 256 / 16 / 30
//!
//! Asserts bit-identical outputs between the kernels first (the wide
//! kernel replays the scalar summation order — see crate::kernel),
//! then times `workers = 1` runs with Hamerly bounds on (the composed
//! gather path) and off (the dense sweep), and emits everything into
//! `BENCH_simd.json`.  Target: ≥2x wide-over-scalar on the default
//! profile with bounds enabled.

use parsample::cluster::engine::{BoundsMode, Engine, LloydLoopResult};
use parsample::cluster::init::{initial_centers, InitMethod};
use parsample::data::synthetic::{make_blobs, BlobSpec};
use parsample::kernel::{KernelMode, TileKernel};
use parsample::util::benchkit::{print_table, Bench};
use parsample::util::json::Json;

fn main() {
    let smoke = std::env::var("PARSAMPLE_BENCH_SMOKE").is_ok();
    let full = std::env::var("PARSAMPLE_BENCH_FULL").is_ok();
    let (m, k, d, iters) = if smoke {
        (2_000usize, 64usize, 8usize, 15usize)
    } else if full {
        (120_000, 256, 16, 30)
    } else {
        (40_000, 96, 16, 30)
    };

    let ds = make_blobs(&BlobSpec {
        num_points: m,
        num_clusters: k,
        dims: d,
        std: 0.05,
        extent: 10.0,
        seed: 42,
    })
    .expect("blob generation");
    let points = ds.as_slice();
    let init = initial_centers(points, d, k, InitMethod::KMeansPlusPlus, 7).expect("init");

    // single-thread engines: this bench isolates the kernel, not the pool
    let engine = |kernel: KernelMode| Engine::new(1).with_kernel(kernel);
    let run = |kernel: KernelMode, bounds: BoundsMode| -> LloydLoopResult {
        engine(kernel).lloyd_loop(points, d, init.clone(), iters, 0.0, bounds)
    };

    // correctness gate before timing anything: the wide kernel must be
    // bit-identical to scalar, bounded and unbounded alike
    let s_ham = run(KernelMode::Scalar, BoundsMode::Hamerly);
    let w_ham = run(KernelMode::Wide, BoundsMode::Hamerly);
    let s_off = run(KernelMode::Scalar, BoundsMode::Off);
    let w_off = run(KernelMode::Wide, BoundsMode::Off);
    for (a, b, ctx) in [(&s_ham, &w_ham, "hamerly"), (&s_off, &w_off, "off")] {
        assert_eq!(a.labels, b.labels, "scalar/wide label mismatch ({ctx})");
        assert_eq!(a.counts, b.counts, "scalar/wide count mismatch ({ctx})");
        assert_eq!(a.centers, b.centers, "scalar/wide center mismatch ({ctx})");
        assert_eq!(
            a.inertia.to_bits(),
            b.inertia.to_bits(),
            "scalar/wide inertia mismatch ({ctx})"
        );
    }
    let auto_is = KernelMode::Auto.resolve(d).name();

    let bench = if smoke { Bench::new(0, 2) } else { Bench::new(1, 5) };
    let t_s_ham =
        bench.run("lloyd/scalar+hamerly", || run(KernelMode::Scalar, BoundsMode::Hamerly));
    let t_w_ham = bench.run("lloyd/wide+hamerly", || run(KernelMode::Wide, BoundsMode::Hamerly));
    let t_s_off = bench.run("lloyd/scalar+off", || run(KernelMode::Scalar, BoundsMode::Off));
    let t_w_off = bench.run("lloyd/wide+off", || run(KernelMode::Wide, BoundsMode::Off));
    let speedup_ham = t_s_ham.mean_ms() / t_w_ham.mean_ms();
    let speedup_off = t_s_off.mean_ms() / t_w_off.mean_ms();

    print_table(
        &format!(
            "SIMD tile kernel — single-thread Lloyd loop (m={m}, k={k}, d={d}, iters={iters}, \
             auto→{auto_is})"
        ),
        &["path", "mean ms", "speedup vs scalar"],
        &[
            vec!["scalar + hamerly".into(), format!("{:.3}", t_s_ham.mean_ms()), "1.00x".into()],
            vec![
                "wide + hamerly".into(),
                format!("{:.3}", t_w_ham.mean_ms()),
                format!("{speedup_ham:.2}x"),
            ],
            vec!["scalar + off".into(), format!("{:.3}", t_s_off.mean_ms()), "1.00x".into()],
            vec![
                "wide + off".into(),
                format!("{:.3}", t_w_off.mean_ms()),
                format!("{speedup_off:.2}x"),
            ],
        ],
    );

    let json = Json::obj(vec![
        ("bench", Json::str("simd_kernel")),
        ("m", Json::num(m as f64)),
        ("k", Json::num(k as f64)),
        ("d", Json::num(d as f64)),
        ("iters", Json::num(iters as f64)),
        ("workers", Json::num(1.0)),
        ("auto_resolves_to", Json::str(auto_is)),
        ("scalar_hamerly_mean_ms", Json::num(t_s_ham.mean_ms())),
        ("wide_hamerly_mean_ms", Json::num(t_w_ham.mean_ms())),
        ("speedup_hamerly", Json::num(speedup_ham)),
        ("scalar_off_mean_ms", Json::num(t_s_off.mean_ms())),
        ("wide_off_mean_ms", Json::num(t_w_off.mean_ms())),
        ("speedup_off", Json::num(speedup_off)),
        ("skip_rate_after_iter5", Json::num(w_ham.stats.skip_rate_from(5))),
    ]);
    let out = "BENCH_simd.json";
    match std::fs::write(out, json.to_string()) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
