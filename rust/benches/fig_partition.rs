//! Bench: Figures 1–2 machinery + ablations.
//!
//! * partitioner cost: equal vs unequal vs random across sizes (the
//!   figures' subclustering step);
//! * §V layout ablation: row-major vs column-major flatten+reconstruct;
//! * scaler ablation: min-max vs z-score fit_transform.

use parsample::data::layout::{flatten, reconstruct, MemoryOrder};
use parsample::data::scaling::{MinMaxScaler, Scaler, ZScoreScaler};
use parsample::data::synthetic::{make_blobs, BlobSpec};
use parsample::partition::{Partitioner, Scheme};
use parsample::util::benchkit::{black_box, print_table, Bench};

fn main() {
    let bench = Bench::new(1, 7);

    // --- partitioner cost (figures' grouping step) ---
    let mut rows = Vec::new();
    for m in [10_000usize, 100_000, 500_000] {
        let data = make_blobs(&BlobSpec {
            num_points: m,
            num_clusters: (m / 500).max(2),
            dims: 2,
            std: 0.08,
            extent: 50.0,
            seed: 1,
        })
        .expect("dataset");
        let scaled = MinMaxScaler::new().fit_transform(&data).expect("scale");
        let g = (m / 1500).clamp(2, 4096);
        for scheme in [Scheme::Equal, Scheme::Unequal, Scheme::Random] {
            let p = scheme.build(0);
            let stats = bench.run(&format!("partition/{}/{m}", p.name()), || {
                p.partition(&scaled, g).expect("partition")
            });
            rows.push(vec![
                p.name().into(),
                format!("{m}"),
                format!("{g}"),
                format!("{:.3}", stats.mean_ms()),
            ]);
        }
    }
    print_table(
        "Partitioner cost (figures 1-2 grouping step)",
        &["scheme", "points", "groups", "mean ms"],
        &rows,
    );

    // --- §V layout ablation ---
    let data = make_blobs(&BlobSpec {
        num_points: 200_000,
        num_clusters: 64,
        dims: 8,
        std: 0.1,
        extent: 10.0,
        seed: 2,
    })
    .expect("dataset");
    let indices: Vec<usize> = (0..data.len()).step_by(2).collect();
    let mut rows = Vec::new();
    for (name, order) in [("row-major", MemoryOrder::RowMajor), ("col-major", MemoryOrder::ColMajor)] {
        let f = bench.run(&format!("flatten/{name}"), || {
            black_box(flatten(&data, &indices, order))
        });
        let flat = flatten(&data, &indices, order);
        let r = bench.run(&format!("reconstruct/{name}"), || {
            black_box(reconstruct(&flat, indices.len(), data.dims(), order).expect("reconstruct"))
        });
        rows.push(vec![
            name.into(),
            format!("{:.3}", f.mean_ms()),
            format!("{:.3}", r.mean_ms()),
        ]);
    }
    print_table(
        "§V layout ablation (100k x 8 selection)",
        &["order", "flatten ms", "reconstruct ms"],
        &rows,
    );

    // --- scaler ablation ---
    let mut rows = Vec::new();
    let s1 = bench.run("scaler/minmax", || {
        MinMaxScaler::new().fit_transform(&data).expect("scale")
    });
    let s2 = bench.run("scaler/zscore", || {
        ZScoreScaler::new().fit_transform(&data).expect("scale")
    });
    rows.push(vec!["min-max".into(), format!("{:.3}", s1.mean_ms())]);
    rows.push(vec!["z-score".into(), format!("{:.3}", s2.mean_ms())]);
    print_table("Scaler ablation (200k x 8)", &["scaler", "fit+transform ms"], &rows);
}
