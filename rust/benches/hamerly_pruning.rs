//! Bench: Hamerly-bounded Lloyd loop vs the unpruned engine loop on a
//! blob workload (the shape where bound pruning pays: most points stop
//! changing clusters after a few iterations).
//!
//! Profiles (points / clusters / dims / iters):
//!   PARSAMPLE_BENCH_SMOKE=1  →  2k / 64 / 8 / 15   (CI rot-guard)
//!   default                  → 40k / 96 / 16 / 30
//!   PARSAMPLE_BENCH_FULL=1   → 120k / 256 / 16 / 30
//!
//! Asserts bit-identical outputs between the two modes (the tentpole
//! contract), then emits skip rates and wall times into
//! `BENCH_hamerly.json` so the perf trajectory records the fraction of
//! point-iterations pruned (expect >50% after iteration ~5).

use parsample::cluster::engine::{BoundsMode, Engine, LloydLoopResult};
use parsample::cluster::init::{initial_centers, InitMethod};
use parsample::data::synthetic::{make_blobs, BlobSpec};
use parsample::util::benchkit::{print_table, Bench};
use parsample::util::json::Json;

fn main() {
    let smoke = std::env::var("PARSAMPLE_BENCH_SMOKE").is_ok();
    let full = std::env::var("PARSAMPLE_BENCH_FULL").is_ok();
    let (m, k, d, iters) = if smoke {
        (2_000usize, 64usize, 8usize, 15usize)
    } else if full {
        (120_000, 256, 16, 30)
    } else {
        (40_000, 96, 16, 30)
    };

    let ds = make_blobs(&BlobSpec {
        num_points: m,
        num_clusters: k,
        dims: d,
        std: 0.05,
        extent: 10.0,
        seed: 42,
    })
    .expect("blob generation");
    let points = ds.as_slice();
    let init = initial_centers(points, d, k, InitMethod::KMeansPlusPlus, 7).expect("init");

    let workers = 4;
    let engine = Engine::new(workers);
    let run = |bounds: BoundsMode| -> LloydLoopResult {
        engine.lloyd_loop(points, d, init.clone(), iters, 0.0, bounds)
    };

    // correctness gate before timing anything: pruning must be
    // bit-identical to the unpruned loop
    let off = run(BoundsMode::Off);
    let ham = run(BoundsMode::Hamerly);
    assert_eq!(off.labels, ham.labels, "bounded/unbounded label mismatch");
    assert_eq!(off.counts, ham.counts, "bounded/unbounded count mismatch");
    assert_eq!(off.centers, ham.centers, "bounded/unbounded center mismatch");
    assert_eq!(
        off.inertia.to_bits(),
        ham.inertia.to_bits(),
        "bounded/unbounded inertia mismatch"
    );
    // rot-guard for the skip counters themselves
    assert_eq!(ham.stats.point_iters(), m as u64 * (ham.iterations as u64 + 1));
    assert!(ham.stats.skipped() > 0, "bounds never skipped a single point-iteration");

    let skip_rate = ham.stats.skip_rate();
    let skip_rate_after_5 = ham.stats.skip_rate_from(5);

    let bench = if smoke { Bench::new(0, 2) } else { Bench::new(1, 5) };
    let s_off = bench.run("lloyd/bounds=off", || run(BoundsMode::Off));
    let s_ham = bench.run("lloyd/bounds=hamerly", || run(BoundsMode::Hamerly));
    let speedup = s_off.mean_ms() / s_ham.mean_ms();

    print_table(
        &format!("Hamerly pruning — Lloyd loop (m={m}, k={k}, d={d}, iters={iters})"),
        &["path", "mean ms", "skip rate", "skip rate ≥ iter 5", "speedup"],
        &[
            vec![
                "bounds=off".into(),
                format!("{:.3}", s_off.mean_ms()),
                "0.000".into(),
                "0.000".into(),
                "1.00x".into(),
            ],
            vec![
                "bounds=hamerly".into(),
                format!("{:.3}", s_ham.mean_ms()),
                format!("{skip_rate:.3}"),
                format!("{skip_rate_after_5:.3}"),
                format!("{speedup:.2}x"),
            ],
        ],
    );

    let json = Json::obj(vec![
        ("bench", Json::str("hamerly_pruning")),
        ("m", Json::num(m as f64)),
        ("k", Json::num(k as f64)),
        ("d", Json::num(d as f64)),
        ("iters", Json::num(iters as f64)),
        ("workers", Json::num(workers as f64)),
        ("off_mean_ms", Json::num(s_off.mean_ms())),
        ("hamerly_mean_ms", Json::num(s_ham.mean_ms())),
        ("speedup", Json::num(speedup)),
        ("skip_rate", Json::num(skip_rate)),
        ("skip_rate_after_iter5", Json::num(skip_rate_after_5)),
        (
            "skipped_per_iter",
            Json::Arr(
                ham.stats
                    .per_iter
                    .iter()
                    .map(|it| Json::num(it.skipped as f64))
                    .collect(),
            ),
        ),
    ]);
    let out = "BENCH_hamerly.json";
    match std::fs::write(out, json.to_string()) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
