//! Bench: Table 3 — execution time vs compression value.
//!
//! Paper (500k elements): c=5 → 6.2 s, c=10 → 5.76 s, c=15 → 4.83 s,
//! c=20 → (blank); time must decrease monotonically with c because the
//! global stage sees M/c pooled centers.
//!
//! Defaults to 100k; `PARSAMPLE_BENCH_FULL=1` runs the paper's 500k.

use parsample::data::synthetic::paper_scaling_dataset;
use parsample::partition::Scheme;
use parsample::pipeline::{PipelineConfig, SubclusterPipeline};
use parsample::util::benchkit::{print_table, Bench};

fn main() {
    let full = std::env::var("PARSAMPLE_BENCH_FULL").is_ok();
    let m: usize = if full { 500_000 } else { 100_000 };
    let k = m / 500;
    let paper = [(5, "6.2"), (10, "5.76"), (15, "4.83"), (20, "(blank)")];
    let data = paper_scaling_dataset(m, 42).expect("dataset");
    let bench = Bench::heavy();

    let mut rows = Vec::new();
    for (c, paper_s) in paper {
        let cfg = PipelineConfig::builder()
            .scheme(Scheme::Unequal)
            .compression(c as f32)
            .final_k(k)
            .weighted_global(true)
            .build()
            .expect("pipeline config");
        let pipeline = SubclusterPipeline::new(cfg);
        let stats = bench.run(&format!("compression/{c}"), || pipeline.run(&data).expect("pipeline run"));
        let r = pipeline.run(&data).expect("pipeline run");
        rows.push(vec![
            format!("{c}"),
            format!("{:.2}", stats.mean_ms() / 1e3),
            format!("{}", r.local_centers),
            format!("{:.1}", r.achieved_compression(m)),
            paper_s.into(),
        ]);
    }
    print_table(
        &format!("Table 3 — execution time vs compression (M={m})"),
        &["compression", "seconds", "local centers", "achieved c", "paper s (500k)"],
        &rows,
    );
}
