//! Bench: the serving subsystem under client load — predict
//! throughput and tail latency vs connection count, JSON lines vs
//! binary frames, micro-batch coalescing off and on.
//!
//! Every scenario runs against one reactor server preloaded with the
//! same fitted model; each client's first reply is parity-gated
//! against a local `predict_batch` (labels, counts, and inertia bits)
//! before any time is recorded — the protocols and the coalescer may
//! only change wall time, never bytes.  Results go to
//! `BENCH_serve.json`.
//!
//! Profiles (rows per predict / requests per client):
//!   PARSAMPLE_BENCH_SMOKE=1  →  32 / 60, 1–2 connections (CI rot-guard)
//!   default                  →  64 / 400, 1–8 connections
//!   PARSAMPLE_BENCH_FULL=1   →  64 / 2000, 1–16 connections

use std::time::Instant;

use parsample::cluster::EngineOpts;
use parsample::data::synthetic::{make_blobs, BlobSpec};
use parsample::model::{ClusterModel, FittedModel, KMeans, Prediction};
use parsample::server::frame::FrameClient;
use parsample::server::{Client, ProtocolMode, Server, ServerConfig};
use parsample::telemetry::EventLog;
use parsample::util::benchkit::{black_box, print_table};
use parsample::util::json::Json;

const DIMS: usize = 8;

struct Scenario {
    binary: bool,
    coalesce_us: u64,
    conns: usize,
}

struct Measured {
    predicts_per_s: f64,
    p50_us: u64,
    p99_us: u64,
}

fn p_quantile(sorted_us: &[u64], q: f64) -> u64 {
    if sorted_us.is_empty() {
        return 0;
    }
    let idx = ((sorted_us.len() as f64 * q).ceil() as usize).max(1) - 1;
    sorted_us[idx.min(sorted_us.len() - 1)]
}

fn assert_parity(labels: &[u32], counts: &[u32], inertia: f64, want: &Prediction, what: &str) {
    assert_eq!(labels, want.labels.as_slice(), "{what}: labels diverge");
    assert_eq!(counts, want.counts.as_slice(), "{what}: counts diverge");
    assert_eq!(
        inertia.to_bits(),
        want.inertia.to_bits(),
        "{what}: inertia diverges"
    );
}

/// Run one scenario: `conns` clients hammer the server with
/// `reqs`-per-client predicts of the same `chunk`; returns throughput
/// and latency quantiles over every request.
fn run_scenario(
    sc: &Scenario,
    model: &FittedModel,
    chunk: &[f32],
    reqs: usize,
) -> Measured {
    let cfg = ServerConfig {
        coalesce_us: sc.coalesce_us,
        protocol: ProtocolMode::Auto,
        events: EventLog::off(),
        preload: vec![("prod".to_string(), model.clone())],
        ..ServerConfig::default()
    };
    let engine: EngineOpts = cfg.engine;
    let mut server = Server::start_with("127.0.0.1:0", cfg).expect("server start");
    let addr = server.addr();
    let want = model.predict_batch_with(chunk, engine).expect("local predict");
    let what = if sc.binary { "binary" } else { "json" };

    let t0 = Instant::now();
    let mut latencies: Vec<u64> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..sc.conns)
            .map(|_| {
                let want = &want;
                s.spawn(move || {
                    let mut lat = Vec::with_capacity(reqs);
                    if sc.binary {
                        let mut client = FrameClient::connect(addr).expect("connect");
                        for i in 0..reqs {
                            let r0 = Instant::now();
                            let (labels, counts, inertia) =
                                client.predict("prod", chunk, DIMS).expect("predict");
                            lat.push(r0.elapsed().as_micros() as u64);
                            if i == 0 {
                                assert_parity(&labels, &counts, inertia, want, what);
                            }
                            black_box(labels);
                        }
                    } else {
                        let mut client = Client::connect(addr).expect("connect");
                        let req = {
                            let rows: Vec<String> = chunk
                                .chunks(DIMS)
                                .map(|r| {
                                    let xs: Vec<String> =
                                        r.iter().map(|x| format!("{x}")).collect();
                                    format!("[{}]", xs.join(","))
                                })
                                .collect();
                            format!(
                                "{{\"cmd\":\"predict\",\"name\":\"prod\",\"points\":[{}]}}",
                                rows.join(",")
                            )
                        };
                        for i in 0..reqs {
                            let r0 = Instant::now();
                            let resp = client.call(&req).expect("predict");
                            lat.push(r0.elapsed().as_micros() as u64);
                            if i == 0 {
                                let v = Json::parse(&resp).expect("json reply");
                                let labels: Vec<u32> = v
                                    .get("labels")
                                    .and_then(Json::as_arr)
                                    .expect("labels")
                                    .iter()
                                    .map(|l| l.as_usize().expect("label") as u32)
                                    .collect();
                                let counts: Vec<u32> = v
                                    .get("counts")
                                    .and_then(Json::as_arr)
                                    .expect("counts")
                                    .iter()
                                    .map(|c| c.as_usize().expect("count") as u32)
                                    .collect();
                                let inertia =
                                    v.get("inertia").and_then(Json::as_f64).expect("inertia");
                                assert_parity(&labels, &counts, inertia, want, what);
                            }
                            black_box(resp);
                        }
                    }
                    lat
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("client thread"))
            .collect()
    });
    let wall_s = t0.elapsed().as_secs_f64();
    server.shutdown();
    latencies.sort_unstable();
    Measured {
        predicts_per_s: (sc.conns * reqs) as f64 / wall_s,
        p50_us: p_quantile(&latencies, 0.50),
        p99_us: p_quantile(&latencies, 0.99),
    }
}

fn main() {
    let smoke = std::env::var("PARSAMPLE_BENCH_SMOKE").is_ok();
    let full = std::env::var("PARSAMPLE_BENCH_FULL").is_ok();
    let (rows_per_predict, reqs, conn_counts): (usize, usize, Vec<usize>) = if smoke {
        (32, 60, vec![1, 2])
    } else if full {
        (64, 2_000, vec![1, 4, 16])
    } else {
        (64, 400, vec![1, 4, 8])
    };

    let data = make_blobs(&BlobSpec {
        num_points: 4_000,
        num_clusters: 8,
        dims: DIMS,
        std: 0.05,
        extent: 10.0,
        seed: 11,
    })
    .expect("blob generation");
    let model = KMeans::new(8).fit(&data).expect("fit");
    let chunk = &data.as_slice()[..rows_per_predict * DIMS];

    let mut scenarios: Vec<Scenario> = Vec::new();
    for &conns in &conn_counts {
        for binary in [false, true] {
            for coalesce_us in [0u64, 200] {
                scenarios.push(Scenario { binary, coalesce_us, conns });
            }
        }
    }

    let mut table: Vec<Vec<String>> = Vec::new();
    let mut results: Vec<Json> = Vec::new();
    for sc in &scenarios {
        let m = run_scenario(sc, &model, chunk, reqs);
        table.push(vec![
            if sc.binary { "binary" } else { "json" }.to_string(),
            format!("{}", sc.conns),
            if sc.coalesce_us == 0 { "off".to_string() } else { format!("{}us", sc.coalesce_us) },
            format!("{:.0}", m.predicts_per_s),
            format!("{}", m.p50_us),
            format!("{}", m.p99_us),
        ]);
        results.push(Json::obj(vec![
            ("protocol", Json::str(if sc.binary { "binary" } else { "json" })),
            ("conns", Json::num(sc.conns as f64)),
            ("coalesce_us", Json::num(sc.coalesce_us as f64)),
            ("predicts_per_s", Json::num(m.predicts_per_s)),
            ("p50_us", Json::num(m.p50_us as f64)),
            ("p99_us", Json::num(m.p99_us as f64)),
        ]));
    }

    print_table(
        &format!(
            "serve load (rows/predict={rows_per_predict}, reqs/client={reqs}, \
             parity-gated, reactor loop)"
        ),
        &["protocol", "conns", "coalesce", "predicts/s", "p50 us", "p99 us"],
        &table,
    );

    let json = Json::obj(vec![
        ("bench", Json::str("serve_load")),
        ("rows_per_predict", Json::num(rows_per_predict as f64)),
        ("reqs_per_client", Json::num(reqs as f64)),
        ("scenarios", Json::Arr(results)),
    ]);
    let out = "BENCH_serve.json";
    match std::fs::write(out, json.to_string()) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
