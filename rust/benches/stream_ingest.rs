//! Bench: streaming (out-of-core) ingestion vs the resident paths.
//!
//! Measures the cost of the `DataSource` redesign on both halves of
//! the lifecycle:
//!
//! * **predict** — `FittedModel::predict_source` over an in-memory
//!   chunked source, a CSV file, and a binary file, vs
//!   `predict_batch` on the resident buffer;
//! * **fit** — `MiniBatchKMeans` via `fit_source` on a `BlobSource`
//!   (no resident dataset at all) vs the resident `fit`.
//!
//! Every streamed result is asserted bit-identical to its resident
//! twin before timing (the redesign's contract), then rows/s for each
//! path goes into `BENCH_stream.json`.
//!
//! Profiles (points / clusters / dims):
//!   PARSAMPLE_BENCH_SMOKE=1  →  20k / 16 / 8   (CI rot-guard)
//!   default                  → 200k / 64 / 8
//!   PARSAMPLE_BENCH_FULL=1   → 500k / 128 / 8

use parsample::data::loader::{save_binary, save_csv};
use parsample::data::source::{
    BinarySource, BlobSource, ChunkedOnly, CsvSource, DataSource, DatasetSource,
};
use parsample::data::synthetic::{make_blobs, BlobSpec};
use parsample::data::Dataset;
use parsample::model::{ClusterModel, FittedModel};
use parsample::util::benchkit::{black_box, print_table, Bench};
use parsample::util::json::Json;

fn main() {
    let smoke = std::env::var("PARSAMPLE_BENCH_SMOKE").is_ok();
    let full = std::env::var("PARSAMPLE_BENCH_FULL").is_ok();
    let (m, k, d) = if smoke {
        (20_000usize, 16usize, 8usize)
    } else if full {
        (500_000, 128, 8)
    } else {
        (200_000, 64, 8)
    };
    let chunk_rows = 8192usize;

    let spec = BlobSpec {
        num_points: m,
        num_clusters: k,
        dims: d,
        std: 0.05,
        extent: 10.0,
        seed: 42,
    };
    let ds = make_blobs(&spec).expect("blob generation");
    let dir = std::env::temp_dir().join(format!("parsample_bench_stream_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("bench tmp dir");
    let plain = Dataset::new(ds.as_slice().to_vec(), d).expect("dataset");
    let csv = dir.join("bench.csv");
    let bin = dir.join("bench.bin");
    save_csv(&plain, &csv).expect("write csv");
    save_binary(&plain, &bin).expect("write bin");

    // one model, fitted resident
    let fitter = parsample::cluster::MiniBatchKMeans {
        k,
        iters: if smoke { 20 } else { 60 },
        ..Default::default()
    };
    let model: FittedModel = fitter.fit(&ds).expect("fit");
    let resident = model.predict_batch(ds.as_slice()).expect("resident predict");

    // ---- correctness gate: every streamed path must be bit-identical
    let check = |src: &mut dyn DataSource, what: &str| {
        let mut labels: Vec<u32> = Vec::new();
        let p = model
            .predict_source(src, |ls| {
                labels.extend_from_slice(ls);
                Ok(())
            })
            .expect(what);
        assert_eq!(labels, resident.labels, "{what}: labels diverge");
        assert_eq!(p.counts, resident.counts, "{what}: counts diverge");
        assert_eq!(
            p.inertia.to_bits(),
            resident.inertia.to_bits(),
            "{what}: inertia diverges"
        );
    };
    check(&mut ChunkedOnly(DatasetSource::new(plain.clone()).with_chunk_rows(chunk_rows)), "mem");
    check(&mut CsvSource::open(&csv, None).expect("open csv").with_chunk_rows(chunk_rows), "csv");
    check(&mut BinarySource::open(&bin).expect("open bin").with_chunk_rows(chunk_rows), "bin");
    // and the no-disk-at-all synthetic stream fits identically
    let stream_fit = {
        let mut src = BlobSource::new(&spec).expect("blob source").with_chunk_rows(chunk_rows);
        fitter.fit_source(&mut src).expect("stream fit")
    };
    assert_eq!(stream_fit.centers(), model.centers(), "blob-stream fit diverges");

    // ---- timings
    let bench = if smoke { Bench::new(0, 2) } else { Bench::new(1, 5) };
    let t_resident = bench.run("predict/resident", || {
        black_box(model.predict_batch(ds.as_slice()).expect("predict"))
    });
    let drain = |src: &mut dyn DataSource| {
        let mut n = 0usize;
        let p = model
            .predict_source(src, |ls| {
                n += ls.len();
                Ok(())
            })
            .expect("stream predict");
        black_box((n, p.inertia))
    };
    let t_mem = bench.run("predict/stream-mem", || {
        drain(&mut ChunkedOnly(DatasetSource::new(plain.clone()).with_chunk_rows(chunk_rows)))
    });
    let t_csv = bench.run("predict/stream-csv", || {
        drain(&mut CsvSource::open(&csv, None).expect("open csv").with_chunk_rows(chunk_rows))
    });
    let t_bin = bench.run("predict/stream-bin", || {
        drain(&mut BinarySource::open(&bin).expect("open bin").with_chunk_rows(chunk_rows))
    });
    let t_fit_res = bench.run("fit/minibatch-resident", || black_box(fitter.fit(&ds).expect("fit")));
    let t_fit_blob = bench.run("fit/minibatch-blobstream", || {
        let mut src = BlobSource::new(&spec).expect("blob source").with_chunk_rows(chunk_rows);
        black_box(fitter.fit_source(&mut src).expect("stream fit"))
    });

    let rows_per_s = |ms: f64| m as f64 / (ms / 1e3);
    let table: Vec<Vec<String>> = [
        ("predict resident", &t_resident),
        ("predict stream-mem", &t_mem),
        ("predict stream-csv", &t_csv),
        ("predict stream-bin", &t_bin),
        ("fit resident", &t_fit_res),
        ("fit blob-stream", &t_fit_blob),
    ]
    .iter()
    .map(|(name, t)| {
        vec![
            name.to_string(),
            format!("{:.3}", t.mean_ms()),
            format!("{:.2}", rows_per_s(t.mean_ms()) / 1e6),
        ]
    })
    .collect();
    print_table(
        &format!("streaming ingestion (m={m}, k={k}, d={d}, chunk_rows={chunk_rows})"),
        &["path", "mean ms", "Mrows/s"],
        &table,
    );

    let json = Json::obj(vec![
        ("bench", Json::str("stream_ingest")),
        ("m", Json::num(m as f64)),
        ("k", Json::num(k as f64)),
        ("d", Json::num(d as f64)),
        ("chunk_rows", Json::num(chunk_rows as f64)),
        ("predict_resident_mean_ms", Json::num(t_resident.mean_ms())),
        ("predict_stream_mem_mean_ms", Json::num(t_mem.mean_ms())),
        ("predict_stream_csv_mean_ms", Json::num(t_csv.mean_ms())),
        ("predict_stream_bin_mean_ms", Json::num(t_bin.mean_ms())),
        ("predict_resident_rows_per_s", Json::num(rows_per_s(t_resident.mean_ms()))),
        ("predict_stream_mem_rows_per_s", Json::num(rows_per_s(t_mem.mean_ms()))),
        ("predict_stream_csv_rows_per_s", Json::num(rows_per_s(t_csv.mean_ms()))),
        ("predict_stream_bin_rows_per_s", Json::num(rows_per_s(t_bin.mean_ms()))),
        ("fit_resident_mean_ms", Json::num(t_fit_res.mean_ms())),
        ("fit_blobstream_mean_ms", Json::num(t_fit_blob.mean_ms())),
    ]);
    let out = "BENCH_stream.json";
    match std::fs::write(out, json.to_string()) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
    std::fs::remove_dir_all(&dir).ok();
}
