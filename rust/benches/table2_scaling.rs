//! Bench: Table 2 — traditional vs parallel k-means across dataset
//! sizes (2-D, 500 points/cluster, K = M/500).
//!
//! Default sizes are scaled down so `cargo bench` finishes quickly;
//! the full paper sizes run with:
//!   PARSAMPLE_BENCH_FULL=1 cargo bench --bench table2_scaling
//! (the full 500k traditional run takes minutes on CPU — that IS the
//! paper's point).  See EXPERIMENTS.md §T2 for the recorded full run.

use parsample::data::synthetic::paper_scaling_dataset;
use parsample::partition::Scheme;
use parsample::pipeline::{traditional_kmeans_restarts, PipelineConfig, SubclusterPipeline};
use parsample::util::benchkit::{print_table, Bench};

fn main() {
    let full = std::env::var("PARSAMPLE_BENCH_FULL").is_ok();
    let sizes: &[usize] = if full {
        &[100_000, 250_000, 500_000]
    } else {
        &[20_000, 50_000, 100_000]
    };
    let paper = [
        (100_000usize, 2.328, 2.78),
        (250_000, 25.6, 4.96),
        (500_000, 156.8, 6.2),
    ];
    let bench = Bench::heavy();
    let mut rows = Vec::new();
    for &m in sizes {
        let k = m / 500;
        let data = paper_scaling_dataset(m, 42).expect("dataset");

        let t_trad = bench.run(&format!("traditional/{m}"), || {
            traditional_kmeans_restarts(&data, k, 25, 0, 1).expect("kmeans")
        });

        let cfg = PipelineConfig::builder()
            .scheme(Scheme::Unequal)
            .compression(5.0)
            .final_k(k)
            .weighted_global(true)
            .build()
            .expect("pipeline config");
        let pipeline = SubclusterPipeline::new(cfg);
        let t_par = bench.run(&format!("parallel/{m}"), || pipeline.run(&data).expect("pipeline run"));

        let paper_row = paper.iter().find(|(pm, _, _)| *pm == m);
        rows.push(vec![
            format!("{m}"),
            format!("{:.2}", t_trad.mean_ms() / 1e3),
            format!("{:.2}", t_par.mean_ms() / 1e3),
            format!("{:.1}x", t_trad.mean_ms() / t_par.mean_ms()),
            paper_row.map_or("—".into(), |(_, a, b)| format!("{a} vs {b}")),
        ]);
    }
    print_table(
        "Table 2 — execution time in seconds (measured | paper C2075)",
        &["size", "traditional", "parallel", "speedup", "paper t vs p"],
        &rows,
    );
}
