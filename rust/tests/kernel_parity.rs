//! Parity suite for the pluggable tile kernels (`crate::kernel`):
//! the 8-lane `WideKernel` against the `ScalarKernel` yardstick.
//!
//! Contract under test:
//!   * the wide kernel replays `distance::dot`'s summation order lane
//!     by lane, so every engine output — labels, counts, f32 sums, f64
//!     inertia, centers, iteration counts — is *bit-identical* to the
//!     scalar kernel's: across dims that exercise every 4-block tail
//!     shape {1, 3, 5, 7, 9, 17}, k values that leave every possible
//!     padded-lane count {1, 2, 7, 8, 9, 13}, point counts smaller
//!     than one lane group, every worker count, and duplicate-center
//!     ties;
//!   * the gather (Hamerly survivor) path composes with the lanes:
//!     under >90% skip rates the bounded wide loop still matches both
//!     the bounded scalar loop and the unbounded wide loop bit for
//!     bit;
//!   * independently of the bit-identity design, a margin-checked
//!     label-parity property holds: if lane arithmetic ever diverged
//!     (e.g. a future lane-width change reassociating the sums), wide
//!     labels could differ from scalar labels only where the scalar
//!     best/second-best gap is within the f32 rounding envelope.

use parsample::cluster::engine::{BoundsMode, Engine, LloydLoopResult};
use parsample::distance::{self, center_norms};
use parsample::kernel::KernelMode;
use parsample::util::rng::Pcg32;

const DIMS: [usize; 6] = [1, 3, 5, 7, 9, 17];
const KS: [usize; 6] = [1, 2, 7, 8, 9, 13];

fn cloud(m: usize, dims: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::seeded(seed);
    (0..m * dims).map(|_| rng.uniform(-8.0, 8.0)).collect()
}

fn scalar_engine(workers: usize) -> Engine {
    Engine::with_blocking(workers, 96, 5).with_kernel(KernelMode::Scalar)
}

fn wide_engine(workers: usize) -> Engine {
    Engine::with_blocking(workers, 96, 5).with_kernel(KernelMode::Wide)
}

#[test]
fn fused_pass_bit_identical_across_kernels() {
    // every 4-block tail shape × every padded-lane count
    for &dims in &DIMS {
        let m = 311;
        let pts = cloud(m, dims, 10 + dims as u64);
        for &k in &KS {
            let centers = pts[..k * dims].to_vec();
            let scalar = scalar_engine(2).assign_accumulate(&pts, dims, &centers);
            for workers in [1usize, 8] {
                let wide = wide_engine(workers).assign_accumulate(&pts, dims, &centers);
                assert_eq!(wide.labels, scalar.labels, "dims={dims} k={k} w={workers}");
                assert_eq!(wide.counts, scalar.counts, "dims={dims} k={k} w={workers}");
                assert_eq!(wide.sums, scalar.sums, "dims={dims} k={k} w={workers}");
                assert_eq!(
                    wide.inertia.to_bits(),
                    scalar.inertia.to_bits(),
                    "dims={dims} k={k} w={workers}"
                );
            }
        }
    }
}

#[test]
fn point_chunks_smaller_than_a_lane_group() {
    // fewer points than one 8-center lane group, and fewer than any
    // chunk: the edge lanes and the short-chunk path must both hold
    for &dims in &[1usize, 5, 9] {
        for m in [1usize, 2, 3, 7] {
            let pts = cloud(m, dims, 40 + (dims * m) as u64);
            // k may exceed m at the engine layer: most centers stay empty
            for k in [1usize, 2, 9] {
                let centers = cloud(k, dims, 77 + k as u64);
                let scalar = scalar_engine(1).assign_accumulate(&pts, dims, &centers);
                let wide = wide_engine(1).assign_accumulate(&pts, dims, &centers);
                assert_eq!(wide.labels, scalar.labels, "dims={dims} m={m} k={k}");
                assert_eq!(wide.sums, scalar.sums, "dims={dims} m={m} k={k}");
                assert_eq!(
                    wide.inertia.to_bits(),
                    scalar.inertia.to_bits(),
                    "dims={dims} m={m} k={k}"
                );
            }
        }
    }
}

#[test]
fn duplicate_center_ties_break_to_lowest_index() {
    // 21 identical centers span three lane groups and multiple tiles:
    // the strict-< lane reduction must keep the lowest index
    let dims = 5;
    let pts = cloud(200, dims, 3);
    let mut centers = Vec::new();
    for _ in 0..21 {
        centers.extend_from_slice(&pts[..dims]);
    }
    // one far-away center that never wins
    centers.extend_from_slice(&vec![1e6f32; dims]);
    let scalar = scalar_engine(2).assign_accumulate(&pts, dims, &centers);
    let wide = wide_engine(2).assign_accumulate(&pts, dims, &centers);
    assert_eq!(wide.labels, scalar.labels);
    assert!(wide.labels.iter().all(|&l| l == 0), "ties must break to center 0");
    assert_eq!(*wide.counts.last().unwrap(), 0, "far center must stay empty");
}

/// Scalar best and second-best squared distances for one point, via
/// the same norm-hoisted expression the kernels use.
fn best2(p: &[f32], centers: &[f32], cnorm: &[f32], dims: usize) -> (usize, f32, f32) {
    let pn = distance::dot(p, p);
    let (mut bi, mut bd, mut b2) = (0usize, f32::INFINITY, f32::INFINITY);
    for (c, cc) in centers.chunks_exact(dims).enumerate() {
        let d = (pn - 2.0 * distance::dot(p, cc) + cnorm[c]).max(0.0);
        if d < bd {
            b2 = bd;
            bd = d;
            bi = c;
        } else if d < b2 {
            b2 = d;
        }
    }
    (bi, bd, b2)
}

#[test]
fn prop_label_parity_within_margin() {
    // The robustness property the acceptance criteria ask for, weaker
    // than bit-identity on purpose: any wide/scalar label disagreement
    // is only permitted where the scalar best/second gap sits inside
    // the worst-case f32 rounding envelope of the distance expression.
    for &dims in &[2usize, 9, 16, 33] {
        let m = 600;
        let pts = cloud(m, dims, 500 + dims as u64);
        let k = 17;
        let centers = cloud(k, dims, 900 + dims as u64);
        let cnorm = center_norms(&centers, dims);
        let wide_labels = wide_engine(4).assign_only(&pts, dims, &centers);
        let rmax = cnorm.iter().fold(0.0f64, |a, &c| a.max((c as f64).sqrt()));
        let eps = (dims as f64 + 16.0) * (2.0f64).powi(-23);
        for (i, p) in pts.chunks_exact(dims).enumerate() {
            let (bi, bd, b2) = best2(p, &centers, &cnorm, dims);
            if wide_labels[i] as usize == bi {
                continue;
            }
            let scale = (distance::dot(p, p) as f64).sqrt() + rmax;
            let margin = 2.0 * eps * scale * scale;
            assert!(
                (b2 as f64 - bd as f64) <= margin,
                "dims={dims} point {i}: wide label {} vs scalar {bi} with gap {} > margin {margin}",
                wide_labels[i],
                b2 - bd
            );
        }
    }
}

fn assert_loops_eq(a: &LloydLoopResult, b: &LloydLoopResult, ctx: &str) {
    assert_eq!(a.labels, b.labels, "{ctx}");
    assert_eq!(a.counts, b.counts, "{ctx}");
    assert_eq!(a.centers, b.centers, "{ctx}");
    assert_eq!(a.inertia.to_bits(), b.inertia.to_bits(), "{ctx}");
    assert_eq!(a.iterations, b.iterations, "{ctx}");
}

#[test]
fn bounded_wide_loop_bit_identical_to_scalar_and_unbounded() {
    for &dims in &[2usize, 7, 17] {
        let m = 500;
        let pts = cloud(m, dims, 60 + dims as u64);
        let init = pts[..11 * dims].to_vec();
        for workers in [1usize, 8] {
            let s_ham = scalar_engine(workers)
                .lloyd_loop(&pts, dims, init.clone(), 12, 0.0, BoundsMode::Hamerly);
            let w_ham = wide_engine(workers)
                .lloyd_loop(&pts, dims, init.clone(), 12, 0.0, BoundsMode::Hamerly);
            let w_off =
                wide_engine(workers).lloyd_loop(&pts, dims, init.clone(), 12, 0.0, BoundsMode::Off);
            assert_loops_eq(&w_ham, &s_ham, &format!("wide-vs-scalar dims={dims} w={workers}"));
            assert_loops_eq(&w_ham, &w_off, &format!("ham-vs-off dims={dims} w={workers}"));
            // the skip decisions are state-driven, so wide and scalar
            // must even prune the same point-iterations
            assert_eq!(w_ham.stats, s_ham.stats, "dims={dims} w={workers}");
        }
    }
}

#[test]
fn gather_compaction_under_heavy_skip() {
    // 16 tight stacks of duplicate points with the stack locations as
    // init: centers land exactly on the stacks after one update, every
    // shift is zero, and from then on every point-iteration is pruned
    // — the >90% skip regime the gather lanes must survive.
    let dims = 4;
    let stacks = 16usize;
    let per = 250usize;
    let locs = cloud(stacks, dims, 99);
    let mut pts = Vec::with_capacity(stacks * per * dims);
    for s in 0..stacks {
        for _ in 0..per {
            pts.extend_from_slice(&locs[s * dims..(s + 1) * dims]);
        }
    }
    let init = locs.clone();
    let scalar =
        scalar_engine(4).lloyd_loop(&pts, dims, init.clone(), 12, 0.0, BoundsMode::Hamerly);
    let wide = wide_engine(4).lloyd_loop(&pts, dims, init, 12, 0.0, BoundsMode::Hamerly);
    assert_loops_eq(&wide, &scalar, "heavy-skip");
    assert_eq!(wide.stats, scalar.stats);
    assert!(
        wide.stats.skip_rate_from(2) > 0.9,
        "expected >90% skips once converged, got {}",
        wide.stats.skip_rate_from(2)
    );
    assert_eq!(wide.counts, vec![per as u32; stacks]);
}

#[test]
fn auto_mode_matches_fixed_kernels() {
    // whatever Auto resolves to on this host, the outputs are the same
    let dims = 9;
    let pts = cloud(400, dims, 8);
    let centers = pts[..10 * dims].to_vec();
    let scalar = scalar_engine(2).assign_accumulate(&pts, dims, &centers);
    let auto = Engine::with_blocking(2, 96, 5)
        .with_kernel(KernelMode::Auto)
        .assign_accumulate(&pts, dims, &centers);
    assert_eq!(auto.labels, scalar.labels);
    assert_eq!(auto.sums, scalar.sums);
    assert_eq!(auto.inertia.to_bits(), scalar.inertia.to_bits());
}
