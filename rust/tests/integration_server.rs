//! Integration: the TCP job server end to end — protocol, concurrent
//! clients, error surfaces, backpressure, stats, and the serve-many
//! fit/predict/models lifecycle.

use parsample::coordinator::SchedulerConfig;
use parsample::data::synthetic::{make_blobs, BlobSpec};
use parsample::model::{ClusterModel, FittedModel, KMeans};
use parsample::server::{Client, Server, ServerConfig};
use parsample::util::json::Json;

fn start_server(queue_depth: usize) -> Server {
    Server::start(
        "127.0.0.1:0",
        SchedulerConfig { queue_depth, ..Default::default() },
    )
    .expect("server start")
}

fn cluster_request(id: u64, m: usize, k: usize) -> String {
    let data = make_blobs(&BlobSpec {
        num_points: m,
        num_clusters: k,
        dims: 2,
        std: 0.05,
        extent: 10.0,
        seed: id,
    })
    .unwrap();
    let points: Vec<String> = (0..data.len())
        .map(|i| {
            let r = data.row(i);
            format!("[{},{}]", r[0], r[1])
        })
        .collect();
    format!(
        "{{\"cmd\":\"cluster\",\"id\":{id},\"points\":[{}],\"k\":{k},\
         \"num_groups\":4,\"compression\":4}}",
        points.join(",")
    )
}

#[test]
fn ping_and_stats() {
    let server = start_server(4);
    let mut client = Client::connect(server.addr()).unwrap();
    let pong = Json::parse(&client.call("{\"cmd\":\"ping\"}").unwrap()).unwrap();
    assert_eq!(pong.get("pong"), Some(&Json::Bool(true)));
    let stats = Json::parse(&client.call("{\"cmd\":\"stats\"}").unwrap()).unwrap();
    assert_eq!(stats.get("ok"), Some(&Json::Bool(true)));
    assert!(stats.get("requests").is_some());
}

#[test]
fn clusters_over_the_wire() {
    let server = start_server(4);
    let mut client = Client::connect(server.addr()).unwrap();
    let resp = client.call(&cluster_request(42, 400, 4)).unwrap();
    let v = Json::parse(&resp).unwrap();
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{resp}");
    assert_eq!(v.get("id").unwrap().as_usize(), Some(42));
    assert_eq!(v.get("centers").unwrap().as_arr().unwrap().len(), 4);
    assert_eq!(v.get("labels").unwrap().as_arr().unwrap().len(), 400);
    assert!(v.get("inertia").unwrap().as_f64().unwrap() >= 0.0);
    assert!(v.get("elapsed_ms").unwrap().as_f64().unwrap() > 0.0);
}

#[test]
fn sequential_requests_reuse_connection() {
    let server = start_server(4);
    let mut client = Client::connect(server.addr()).unwrap();
    for id in 0..5 {
        let v = Json::parse(&client.call(&cluster_request(id, 200, 3)).unwrap()).unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(v.get("id").unwrap().as_usize(), Some(id as usize));
    }
    // stats reflect the five completions
    let stats = Json::parse(&client.call("{\"cmd\":\"stats\"}").unwrap()).unwrap();
    assert_eq!(stats.get("completed").unwrap().as_usize(), Some(5));
}

#[test]
fn concurrent_clients() {
    let server = start_server(8);
    let addr = server.addr();
    std::thread::scope(|s| {
        for t in 0..4 {
            s.spawn(move || {
                let mut client = Client::connect(addr).unwrap();
                for i in 0..3 {
                    let id = (t * 10 + i) as u64;
                    let v = Json::parse(&client.call(&cluster_request(id, 300, 3)).unwrap())
                        .unwrap();
                    assert_eq!(v.get("ok"), Some(&Json::Bool(true)));
                    assert_eq!(v.get("id").unwrap().as_usize(), Some(id as usize));
                }
            });
        }
    });
    assert!(server.latency.count() >= 12);
}

#[test]
fn malformed_requests_get_error_responses() {
    let server = start_server(4);
    let mut client = Client::connect(server.addr()).unwrap();
    for bad in [
        "not json at all",
        "{\"cmd\":\"warp\"}",
        "{\"cmd\":\"cluster\",\"k\":3}",
        "{\"cmd\":\"cluster\",\"points\":[[1,2],[3]],\"k\":1}",
    ] {
        let v = Json::parse(&client.call(bad).unwrap()).unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(false)), "input: {bad}");
        assert!(v.get("error").unwrap().as_str().unwrap().len() > 3);
    }
    // connection still usable after errors
    let v = Json::parse(&client.call(&cluster_request(1, 100, 2)).unwrap()).unwrap();
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)));
}

#[test]
fn job_level_failures_are_reported_not_fatal() {
    let server = start_server(4);
    let mut client = Client::connect(server.addr()).unwrap();
    // k greater than the number of points -> pipeline error, ok:false
    let req = "{\"cmd\":\"cluster\",\"id\":9,\"points\":[[1,2],[3,4]],\"k\":50}";
    let v = Json::parse(&client.call(req).unwrap()).unwrap();
    assert_eq!(v.get("ok"), Some(&Json::Bool(false)));
    assert_eq!(v.get("id").unwrap().as_usize(), Some(9));
    // server alive
    let v = Json::parse(&client.call("{\"cmd\":\"ping\"}").unwrap()).unwrap();
    assert_eq!(v.get("pong"), Some(&Json::Bool(true)));
}

#[test]
fn shutdown_is_clean() {
    let mut server = start_server(2);
    let addr = server.addr();
    {
        let mut client = Client::connect(addr).unwrap();
        let _ = client.call("{\"cmd\":\"ping\"}").unwrap();
    }
    server.shutdown();
    // further connections fail or are closed immediately without hanging
    if let Ok(mut c) = Client::connect(addr) {
        let _ = c.call("{\"cmd\":\"ping\"}");
    }
}

#[test]
fn shutdown_returns_promptly_with_idle_connection_open() {
    let mut server = start_server(2);
    let addr = server.addr();
    // a client that connects, speaks once, then just sits on the
    // connection — the old blocking read would park the handler (and
    // the accept loop's final join) forever
    let mut idle = Client::connect(addr).unwrap();
    let _ = idle.call("{\"cmd\":\"ping\"}").unwrap();
    let mut fresh = Client::connect(addr).unwrap(); // never sends anything
    let t0 = std::time::Instant::now();
    server.shutdown();
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(5),
        "shutdown took {:?} with idle connections open",
        t0.elapsed()
    );
    // the idle connections are now dead
    let idle_dead = idle.call("{\"cmd\":\"ping\"}").is_err();
    let fresh_dead = fresh.call("{\"cmd\":\"ping\"}").is_err();
    assert!(idle_dead || fresh_dead);
}

/// Row-major points → the protocol's nested-array form.
fn points_json(points: &[f32], dims: usize) -> String {
    let rows: Vec<String> = points
        .chunks(dims)
        .map(|r| {
            let xs: Vec<String> = r.iter().map(|x| format!("{x}")).collect();
            format!("[{}]", xs.join(","))
        })
        .collect();
    format!("[{}]", rows.join(","))
}

fn fit_request(name: &str, algo: &str, m: usize, k: usize) -> (String, Vec<f32>) {
    let data = make_blobs(&BlobSpec {
        num_points: m,
        num_clusters: k,
        dims: 2,
        std: 0.05,
        extent: 10.0,
        seed: 99,
    })
    .unwrap();
    let pts = data.as_slice().to_vec();
    let req = format!(
        "{{\"cmd\":\"fit\",\"name\":\"{name}\",\"algorithm\":\"{algo}\",\
         \"points\":{},\"k\":{k},\"num_groups\":4,\"compression\":4}}",
        points_json(&pts, 2)
    );
    (req, pts)
}

#[test]
fn fit_predict_models_over_the_wire() {
    let server = start_server(4);
    let mut client = Client::connect(server.addr()).unwrap();

    // registry starts empty
    let v = Json::parse(&client.call("{\"cmd\":\"models\"}").unwrap()).unwrap();
    assert_eq!(v.get("count").unwrap().as_usize(), Some(0));

    // fit once…
    let (req, pts) = fit_request("prod", "kmeans", 300, 3);
    let v = Json::parse(&client.call(&req).unwrap()).unwrap();
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v:?}");
    assert_eq!(v.get("name").unwrap().as_str(), Some("prod"));
    assert_eq!(v.get("algorithm").unwrap().as_str(), Some("kmeans"));
    assert_eq!(v.get("k").unwrap().as_usize(), Some(3));
    assert_eq!(v.get("trained_on").unwrap().as_usize(), Some(300));

    // …predict many (small batches, no re-clustering)
    for chunk in pts.chunks(2 * 10).take(5) {
        let req = format!(
            "{{\"cmd\":\"predict\",\"name\":\"prod\",\"points\":{}}}",
            points_json(chunk, 2)
        );
        let v = Json::parse(&client.call(&req).unwrap()).unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v:?}");
        assert_eq!(
            v.get("labels").unwrap().as_arr().unwrap().len(),
            chunk.len() / 2
        );
        assert_eq!(v.get("counts").unwrap().as_arr().unwrap().len(), 3);
        assert!(v.get("inertia").unwrap().as_f64().unwrap() >= 0.0);
    }

    // the registry lists it
    let v = Json::parse(&client.call("{\"cmd\":\"models\"}").unwrap()).unwrap();
    assert_eq!(v.get("count").unwrap().as_usize(), Some(1));
    let row = &v.get("models").unwrap().as_arr().unwrap()[0];
    assert_eq!(row.get("name").unwrap().as_str(), Some("prod"));

    // serve-many error surfaces: unknown model, dims mismatch
    let v = Json::parse(
        &client
            .call("{\"cmd\":\"predict\",\"name\":\"nope\",\"points\":[[1,2]]}")
            .unwrap(),
    )
    .unwrap();
    assert_eq!(v.get("ok"), Some(&Json::Bool(false)));
    assert!(v.get("error").unwrap().as_str().unwrap().contains("unknown model"));
    let v = Json::parse(
        &client
            .call("{\"cmd\":\"predict\",\"name\":\"prod\",\"points\":[[1,2,3]]}")
            .unwrap(),
    )
    .unwrap();
    assert_eq!(v.get("ok"), Some(&Json::Bool(false)));

    // fit-level failures are reported, not fatal: k > points
    let v = Json::parse(
        &client
            .call("{\"cmd\":\"fit\",\"name\":\"bad\",\"algorithm\":\"kmeans\",\"points\":[[1,2],[3,4]],\"k\":50}")
            .unwrap(),
    )
    .unwrap();
    assert_eq!(v.get("ok"), Some(&Json::Bool(false)));
    // unknown algorithm too
    let v = Json::parse(
        &client
            .call("{\"cmd\":\"fit\",\"name\":\"bad\",\"algorithm\":\"dbscan\",\"points\":[[1,2],[3,4]],\"k\":1}")
            .unwrap(),
    )
    .unwrap();
    assert_eq!(v.get("ok"), Some(&Json::Bool(false)));
    // connection still usable
    let v = Json::parse(&client.call("{\"cmd\":\"ping\"}").unwrap()).unwrap();
    assert_eq!(v.get("pong"), Some(&Json::Bool(true)));
}

/// Acceptance: a model artifact that went through a save/load
/// roundtrip (the CLI `fit` → `serve --models` path) answers server
/// predict requests, bit-identically to a local predict.
#[test]
fn preloaded_saved_model_answers_predicts() {
    let data = make_blobs(&BlobSpec {
        num_points: 400,
        num_clusters: 4,
        dims: 2,
        std: 0.05,
        extent: 10.0,
        seed: 5,
    })
    .unwrap();
    // fit + save exactly like `parsample fit --out` does
    let model = KMeans::new(4).fit(&data).unwrap();
    let dir = std::env::temp_dir().join(format!("parsample_srv_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("prod.model.json");
    model.save(&path).unwrap();
    let local = model.predict_dataset(&data).unwrap();

    // load exactly like `serve --models` does, and preload
    let loaded = FittedModel::load(&path).unwrap();
    let mut cfg = ServerConfig::from_scheduler(SchedulerConfig {
        queue_depth: 4,
        ..Default::default()
    });
    cfg.preload = vec![("prod".to_string(), loaded)];
    let server = Server::start_with("127.0.0.1:0", cfg).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();

    let req = format!(
        "{{\"cmd\":\"predict\",\"name\":\"prod\",\"points\":{}}}",
        points_json(data.as_slice(), 2)
    );
    let v = Json::parse(&client.call(&req).unwrap()).unwrap();
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v:?}");
    let labels: Vec<u32> = v
        .get("labels")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|l| l.as_usize().unwrap() as u32)
        .collect();
    assert_eq!(labels, local.labels);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn registry_evicts_lru_over_the_wire() {
    let mut cfg = ServerConfig::from_scheduler(SchedulerConfig {
        queue_depth: 4,
        ..Default::default()
    });
    cfg.model_cap = 2;
    let server = Server::start_with("127.0.0.1:0", cfg).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();
    for name in ["a", "b", "c"] {
        let (req, _) = fit_request(name, "kmeans", 60, 2);
        let v = Json::parse(&client.call(&req).unwrap()).unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{name}");
    }
    let v = Json::parse(&client.call("{\"cmd\":\"models\"}").unwrap()).unwrap();
    assert_eq!(v.get("count").unwrap().as_usize(), Some(2));
    let names: Vec<&str> = v
        .get("models")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|m| m.get("name").unwrap().as_str().unwrap())
        .collect();
    assert_eq!(names, vec!["b", "c"], "oldest fit evicted first");
}

/// A request line with invalid UTF-8 gets an error response instead of
/// corrupting the stream or killing the connection — the handler reads
/// raw bytes (timeouts can split multi-byte characters) and validates
/// once per complete line.
#[test]
fn invalid_utf8_line_is_rejected_not_fatal() {
    use std::io::{BufRead, BufReader, Write};
    let server = start_server(2);
    let mut stream = std::net::TcpStream::connect(server.addr()).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    stream.write_all(b"{\"cmd\":\"ping\xff\xfe\"}\n").unwrap();
    stream.flush().unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = Json::parse(line.trim_end()).unwrap();
    assert_eq!(v.get("ok"), Some(&Json::Bool(false)));
    assert!(v.get("error").unwrap().as_str().unwrap().contains("utf-8"));
    // the connection survives and serves the next request
    stream.write_all(b"{\"cmd\":\"ping\"}\n").unwrap();
    stream.flush().unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = Json::parse(line.trim_end()).unwrap();
    assert_eq!(v.get("pong"), Some(&Json::Bool(true)));
}

/// The CI smoke: one fit, one predict, clean shutdown on an ephemeral
/// port.  Keeps the serve-many path and the shutdown fix green.
#[test]
fn server_fit_predict_shutdown_smoke() {
    let mut server = start_server(4);
    let mut client = Client::connect(server.addr()).unwrap();
    let (req, pts) = fit_request("smoke", "pipeline", 400, 3);
    let v = Json::parse(&client.call(&req).unwrap()).unwrap();
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v:?}");
    let req = format!(
        "{{\"cmd\":\"predict\",\"name\":\"smoke\",\"points\":{}}}",
        points_json(&pts[..20], 2)
    );
    let v = Json::parse(&client.call(&req).unwrap()).unwrap();
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v:?}");
    assert_eq!(v.get("labels").unwrap().as_arr().unwrap().len(), 10);
    let t0 = std::time::Instant::now();
    server.shutdown();
    assert!(t0.elapsed() < std::time::Duration::from_secs(5));
}

/// Satellite: per-model predict counters surface in `stats`,
/// incremented by the chunked predict path.
#[test]
fn stats_reports_per_model_predict_counters() {
    let server = start_server(4);
    let mut client = Client::connect(server.addr()).unwrap();
    // no models yet: empty counter list
    let v = Json::parse(&client.call("{\"cmd\":\"stats\"}").unwrap()).unwrap();
    assert_eq!(v.get("models").unwrap().as_arr().unwrap().len(), 0);

    let (req, pts) = fit_request("ctr", "kmeans", 200, 2);
    let v = Json::parse(&client.call(&req).unwrap()).unwrap();
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v:?}");
    for chunk in pts.chunks(2 * 8).take(3) {
        let req = format!(
            "{{\"cmd\":\"predict\",\"name\":\"ctr\",\"points\":{}}}",
            points_json(chunk, 2)
        );
        let v = Json::parse(&client.call(&req).unwrap()).unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v:?}");
    }
    // a failed predict (unknown model) must not count anywhere
    let _ = client.call("{\"cmd\":\"predict\",\"name\":\"ghost\",\"points\":[[1,2]]}");

    let v = Json::parse(&client.call("{\"cmd\":\"stats\"}").unwrap()).unwrap();
    let models = v.get("models").unwrap().as_arr().unwrap();
    assert_eq!(models.len(), 1, "{v:?}");
    assert_eq!(models[0].get("name").unwrap().as_str(), Some("ctr"));
    assert_eq!(models[0].get("predicts").unwrap().as_usize(), Some(3));
}

/// Satellite: with `--snapshot-dir`, a shutdown writes the registered
/// artifacts and the next boot reloads them — the restarted server
/// answers predicts without any refit, bit-identically.
#[test]
fn registry_snapshot_survives_restart() {
    let dir = std::env::temp_dir().join(format!("parsample_snap_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();

    let mk_cfg = || {
        let mut cfg = ServerConfig::from_scheduler(SchedulerConfig {
            queue_depth: 4,
            ..Default::default()
        });
        cfg.snapshot_dir = Some(dir.clone());
        cfg
    };

    // first life: fit a model over the wire, shut down
    let mut server = Server::start_with("127.0.0.1:0", mk_cfg()).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();
    let (req, pts) = fit_request("warm", "kmeans", 300, 3);
    let v = Json::parse(&client.call(&req).unwrap()).unwrap();
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v:?}");
    let req = format!(
        "{{\"cmd\":\"predict\",\"name\":\"warm\",\"points\":{}}}",
        points_json(&pts, 2)
    );
    let v = Json::parse(&client.call(&req).unwrap()).unwrap();
    let labels_before: Vec<usize> = v
        .get("labels")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|l| l.as_usize().unwrap())
        .collect();
    drop(client);
    server.shutdown();
    assert!(dir.join("warm.model.json").exists(), "snapshot file written");

    // second life: no preload, no fit — the snapshot warms the boot
    let mut server = Server::start_with("127.0.0.1:0", mk_cfg()).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();
    let v = Json::parse(&client.call("{\"cmd\":\"models\"}").unwrap()).unwrap();
    assert_eq!(v.get("count").unwrap().as_usize(), Some(1), "{v:?}");
    let req = format!(
        "{{\"cmd\":\"predict\",\"name\":\"warm\",\"points\":{}}}",
        points_json(&pts, 2)
    );
    let v = Json::parse(&client.call(&req).unwrap()).unwrap();
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v:?}");
    let labels_after: Vec<usize> = v
        .get("labels")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|l| l.as_usize().unwrap())
        .collect();
    assert_eq!(labels_after, labels_before, "warm model predicts bit-identically");
    drop(client);
    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}
