//! Integration: the TCP job server end to end — protocol, concurrent
//! clients, error surfaces, backpressure, stats.

use parsample::coordinator::SchedulerConfig;
use parsample::data::synthetic::{make_blobs, BlobSpec};
use parsample::server::{Client, Server};
use parsample::util::json::Json;

fn start_server(queue_depth: usize) -> Server {
    Server::start(
        "127.0.0.1:0",
        SchedulerConfig { queue_depth, ..Default::default() },
    )
    .expect("server start")
}

fn cluster_request(id: u64, m: usize, k: usize) -> String {
    let data = make_blobs(&BlobSpec {
        num_points: m,
        num_clusters: k,
        dims: 2,
        std: 0.05,
        extent: 10.0,
        seed: id,
    })
    .unwrap();
    let points: Vec<String> = (0..data.len())
        .map(|i| {
            let r = data.row(i);
            format!("[{},{}]", r[0], r[1])
        })
        .collect();
    format!(
        "{{\"cmd\":\"cluster\",\"id\":{id},\"points\":[{}],\"k\":{k},\
         \"num_groups\":4,\"compression\":4}}",
        points.join(",")
    )
}

#[test]
fn ping_and_stats() {
    let server = start_server(4);
    let mut client = Client::connect(server.addr()).unwrap();
    let pong = Json::parse(&client.call("{\"cmd\":\"ping\"}").unwrap()).unwrap();
    assert_eq!(pong.get("pong"), Some(&Json::Bool(true)));
    let stats = Json::parse(&client.call("{\"cmd\":\"stats\"}").unwrap()).unwrap();
    assert_eq!(stats.get("ok"), Some(&Json::Bool(true)));
    assert!(stats.get("requests").is_some());
}

#[test]
fn clusters_over_the_wire() {
    let server = start_server(4);
    let mut client = Client::connect(server.addr()).unwrap();
    let resp = client.call(&cluster_request(42, 400, 4)).unwrap();
    let v = Json::parse(&resp).unwrap();
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{resp}");
    assert_eq!(v.get("id").unwrap().as_usize(), Some(42));
    assert_eq!(v.get("centers").unwrap().as_arr().unwrap().len(), 4);
    assert_eq!(v.get("labels").unwrap().as_arr().unwrap().len(), 400);
    assert!(v.get("inertia").unwrap().as_f64().unwrap() >= 0.0);
    assert!(v.get("elapsed_ms").unwrap().as_f64().unwrap() > 0.0);
}

#[test]
fn sequential_requests_reuse_connection() {
    let server = start_server(4);
    let mut client = Client::connect(server.addr()).unwrap();
    for id in 0..5 {
        let v = Json::parse(&client.call(&cluster_request(id, 200, 3)).unwrap()).unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(v.get("id").unwrap().as_usize(), Some(id as usize));
    }
    // stats reflect the five completions
    let stats = Json::parse(&client.call("{\"cmd\":\"stats\"}").unwrap()).unwrap();
    assert_eq!(stats.get("completed").unwrap().as_usize(), Some(5));
}

#[test]
fn concurrent_clients() {
    let server = start_server(8);
    let addr = server.addr();
    std::thread::scope(|s| {
        for t in 0..4 {
            s.spawn(move || {
                let mut client = Client::connect(addr).unwrap();
                for i in 0..3 {
                    let id = (t * 10 + i) as u64;
                    let v = Json::parse(&client.call(&cluster_request(id, 300, 3)).unwrap())
                        .unwrap();
                    assert_eq!(v.get("ok"), Some(&Json::Bool(true)));
                    assert_eq!(v.get("id").unwrap().as_usize(), Some(id as usize));
                }
            });
        }
    });
    assert!(server.latency.count() >= 12);
}

#[test]
fn malformed_requests_get_error_responses() {
    let server = start_server(4);
    let mut client = Client::connect(server.addr()).unwrap();
    for bad in [
        "not json at all",
        "{\"cmd\":\"warp\"}",
        "{\"cmd\":\"cluster\",\"k\":3}",
        "{\"cmd\":\"cluster\",\"points\":[[1,2],[3]],\"k\":1}",
    ] {
        let v = Json::parse(&client.call(bad).unwrap()).unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(false)), "input: {bad}");
        assert!(v.get("error").unwrap().as_str().unwrap().len() > 3);
    }
    // connection still usable after errors
    let v = Json::parse(&client.call(&cluster_request(1, 100, 2)).unwrap()).unwrap();
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)));
}

#[test]
fn job_level_failures_are_reported_not_fatal() {
    let server = start_server(4);
    let mut client = Client::connect(server.addr()).unwrap();
    // k greater than the number of points -> pipeline error, ok:false
    let req = "{\"cmd\":\"cluster\",\"id\":9,\"points\":[[1,2],[3,4]],\"k\":50}";
    let v = Json::parse(&client.call(req).unwrap()).unwrap();
    assert_eq!(v.get("ok"), Some(&Json::Bool(false)));
    assert_eq!(v.get("id").unwrap().as_usize(), Some(9));
    // server alive
    let v = Json::parse(&client.call("{\"cmd\":\"ping\"}").unwrap()).unwrap();
    assert_eq!(v.get("pong"), Some(&Json::Bool(true)));
}

#[test]
fn shutdown_is_clean() {
    let mut server = start_server(2);
    let addr = server.addr();
    {
        let mut client = Client::connect(addr).unwrap();
        let _ = client.call("{\"cmd\":\"ping\"}").unwrap();
    }
    server.shutdown();
    // further connections fail or are closed immediately without hanging
    if let Ok(mut c) = Client::connect(addr) {
        let _ = c.call("{\"cmd\":\"ping\"}");
    }
}
