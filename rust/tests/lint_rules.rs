//! Fixture-driven tests for `parsample-lint`: each rule has a
//! violating and a clean snippet under `tests/analysis_fixtures/`, and
//! the suite asserts exact rule/line hits, allowlist suppression, and
//! — the gate that matters — that `src/` itself is clean at HEAD.

use std::path::{Path, PathBuf};

use parsample::analysis::{emit_jsonl, lint_file, lint_tree, rule_id, Allowlist, LintReport};
use parsample::telemetry::events::EventLog;
use parsample::util::json::Json;

fn fixtures() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/analysis_fixtures")
}

/// `(rule, line)` pairs for one fixture, sorted by line.
fn hits(rel: &str) -> Vec<(&'static str, usize)> {
    let findings = lint_file(&fixtures().join(rel)).expect("fixture readable");
    findings.iter().map(|f| (f.rule, f.line)).collect()
}

#[test]
fn unsafe_without_safety_comment_is_flagged() {
    assert_eq!(hits("unsafe_bad.rs"), vec![(rule_id::UNSAFE_SAFETY, 4)]);
    assert_eq!(hits("unsafe_ok.rs"), vec![]);
}

#[test]
fn condvar_wait_outside_loop_is_flagged() {
    assert_eq!(hits("condvar_bad.rs"), vec![(rule_id::CONDVAR_WAIT, 8)]);
    assert_eq!(hits("condvar_ok.rs"), vec![]);
}

#[test]
fn undocumented_lock_poisoning_is_flagged() {
    assert_eq!(hits("mutex_bad.rs"), vec![(rule_id::MUTEX_POISON, 6)]);
    assert_eq!(hits("mutex_ok.rs"), vec![]);
}

#[test]
fn contract_regions_forbid_nondeterminism_sources() {
    let got = hits("contract_bad/cluster/engine.rs");
    let want: Vec<(&str, usize)> =
        [3, 4, 6, 7, 8, 17].iter().map(|&l| (rule_id::CONTRACT_FORBIDDEN, l)).collect();
    assert_eq!(got, want);
}

#[test]
fn determinism_paths_must_carry_the_annotation() {
    assert_eq!(
        hits("contract_missing/cluster/engine.rs"),
        vec![(rule_id::CONTRACT_ANNOTATION, 1)]
    );
    assert_eq!(hits("contract_ok/cluster/engine.rs"), vec![]);
}

#[test]
fn panic_paths_in_server_code_are_flagged() {
    let got = hits("panic_bad/server/handlers.rs");
    let want: Vec<(&str, usize)> =
        [4, 6, 12, 16].iter().map(|&l| (rule_id::NO_PANIC, l)).collect();
    assert_eq!(got, want);
    assert_eq!(hits("panic_ok/server/handlers.rs"), vec![]);
}

#[test]
fn protocol_drift_is_flagged_per_entry() {
    let got = hits("proto_bad/server/protocol.rs");
    let want: Vec<(&str, usize)> =
        [10, 12, 12, 12, 19].iter().map(|&l| (rule_id::PROTOCOL_COVERAGE, l)).collect();
    assert_eq!(got, want);
    assert_eq!(hits("proto_ok/server/protocol.rs"), vec![]);
}

#[test]
fn tree_lint_totals_and_allowlist_suppression() {
    // empty allowlist: every violating fixture contributes
    let bare = lint_tree(&fixtures(), &Allowlist::empty()).expect("tree lints");
    assert_eq!(bare.findings.len(), 19, "findings: {:#?}", bare.findings);
    assert!(bare.suppressed.is_empty());
    assert!(bare.unused_allow.is_empty());
    assert!(!bare.clean());

    // one narrow entry: exactly the mutex fixture finding disappears
    let allow = Allowlist::parse(
        "inline.toml",
        "[[allow]]\nrule = \"mutex-poison-doc\"\nfile = \"mutex_bad.rs\"\nline = 6\nreason = \"fixture demo\"\n",
    )
    .expect("allowlist parses");
    let report = lint_tree(&fixtures(), &allow).expect("tree lints");
    assert_eq!(report.findings.len(), 18);
    assert_eq!(report.suppressed.len(), 1);
    assert_eq!(report.suppressed[0].0.rule, rule_id::MUTEX_POISON);
    assert_eq!(report.suppressed[0].1, "fixture demo");
    assert!(report.unused_allow.is_empty());

    // an entry that matches nothing fails the build as unused-allow
    let stale = Allowlist::parse(
        "inline.toml",
        "[[allow]]\nrule = \"unsafe-safety\"\nfile = \"no_such_file.rs\"\nreason = \"stale\"\n",
    )
    .expect("allowlist parses");
    let report = lint_tree(&fixtures(), &stale).expect("tree lints");
    assert_eq!(report.unused_allow.len(), 1);
    assert_eq!(report.unused_allow[0].rule, rule_id::UNUSED_ALLOW);
    assert!(!report.clean());
}

#[test]
fn jsonl_output_is_reason_tagged_and_parseable() {
    let allow = Allowlist::parse(
        "inline.toml",
        "[[allow]]\nrule = \"mutex-poison-doc\"\nfile = \"mutex_bad.rs\"\nreason = \"fixture demo\"\n",
    )
    .expect("allowlist parses");
    let report = lint_tree(&fixtures(), &allow).expect("tree lints");
    let log = EventLog::capture();
    emit_jsonl(&report, &log);
    let lines = log.captured();
    assert_eq!(lines.len(), report.findings.len() + report.suppressed.len() + 1);
    assert_eq!(log.count("lint-finding"), report.findings.len());
    assert_eq!(log.count("lint-allowed"), 1);
    assert_eq!(log.count("lint-summary"), 1);
    for line in &lines {
        assert!(line.starts_with("{\"reason\":\"lint-"), "bad prefix: {line}");
        let v = Json::parse(line).unwrap_or_else(|e| panic!("bad JSONL {line}: {e:?}"));
        assert!(v.get("reason").and_then(Json::as_str).is_some());
    }
    let finding = Json::parse(&lines[0]).expect("finding line parses");
    assert!(finding.get("rule").and_then(Json::as_str).is_some());
    assert!(finding.get("file").and_then(Json::as_str).is_some());
    assert!(finding.get("line").and_then(Json::as_usize).is_some());
    let summary = Json::parse(lines.last().expect("summary line")).expect("summary parses");
    assert_eq!(
        summary.get("failing").and_then(Json::as_usize),
        Some(report.findings.len() + report.unused_allow.len())
    );
}

/// The acceptance gate: the repo's own `src/` tree is lint-clean at
/// HEAD under the checked-in allowlist.
#[test]
fn repo_src_is_clean_under_checked_in_allowlist() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let allow = Allowlist::load(&root.join("analysis/allow.toml")).expect("allow.toml parses");
    let report: LintReport = lint_tree(&root, &allow).expect("src lints");
    assert!(
        report.clean(),
        "src/ has {} lint finding(s):\n{:#?}\nunused allow entries: {:#?}",
        report.findings.len(),
        report.findings,
        report.unused_allow
    );
    assert!(report.files > 40, "walk looks truncated: {} files", report.files);
}
