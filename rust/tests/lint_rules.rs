//! Fixture-driven tests for `parsample-lint`: each rule has a
//! violating and a clean snippet under `tests/analysis_fixtures/`, and
//! the suite asserts exact rule/line hits, allowlist suppression, and
//! — the gate that matters — that `src/` itself is clean at HEAD.

use std::path::{Path, PathBuf};

use parsample::analysis::{
    emit_graph_jsonl, emit_jsonl, lint_file, lint_tree, lint_tree_with_aux, rule_id, Allowlist,
    LintReport,
};
use parsample::telemetry::events::EventLog;
use parsample::util::json::Json;

fn fixtures() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/analysis_fixtures")
}

/// `(rule, line)` pairs for one fixture, sorted by line.
fn hits(rel: &str) -> Vec<(&'static str, usize)> {
    let findings = lint_file(&fixtures().join(rel)).expect("fixture readable");
    findings.iter().map(|f| (f.rule, f.line)).collect()
}

#[test]
fn unsafe_without_safety_comment_is_flagged() {
    assert_eq!(hits("unsafe_bad.rs"), vec![(rule_id::UNSAFE_SAFETY, 4)]);
    assert_eq!(hits("unsafe_ok.rs"), vec![]);
}

#[test]
fn condvar_wait_outside_loop_is_flagged() {
    assert_eq!(hits("condvar_bad.rs"), vec![(rule_id::CONDVAR_WAIT, 8)]);
    assert_eq!(hits("condvar_ok.rs"), vec![]);
}

#[test]
fn undocumented_lock_poisoning_is_flagged() {
    assert_eq!(hits("mutex_bad.rs"), vec![(rule_id::MUTEX_POISON, 6)]);
    assert_eq!(hits("mutex_ok.rs"), vec![]);
}

#[test]
fn contract_regions_forbid_nondeterminism_sources() {
    let got = hits("contract_bad/cluster/engine.rs");
    let want: Vec<(&str, usize)> =
        [3, 4, 6, 7, 8, 17].iter().map(|&l| (rule_id::CONTRACT_FORBIDDEN, l)).collect();
    assert_eq!(got, want);
}

#[test]
fn determinism_paths_must_carry_the_annotation() {
    assert_eq!(
        hits("contract_missing/cluster/engine.rs"),
        vec![(rule_id::CONTRACT_ANNOTATION, 1)]
    );
    assert_eq!(hits("contract_ok/cluster/engine.rs"), vec![]);
}

#[test]
fn panic_paths_in_server_code_are_flagged() {
    let got = hits("panic_bad/server/handlers.rs");
    let want: Vec<(&str, usize)> =
        [4, 6, 12, 16].iter().map(|&l| (rule_id::NO_PANIC, l)).collect();
    assert_eq!(got, want);
    assert_eq!(hits("panic_ok/server/handlers.rs"), vec![]);
}

#[test]
fn protocol_drift_is_flagged_per_entry() {
    let got = hits("proto_bad/server/protocol.rs");
    let want: Vec<(&str, usize)> =
        [10, 12, 12, 12, 19].iter().map(|&l| (rule_id::PROTOCOL_COVERAGE, l)).collect();
    assert_eq!(got, want);
    assert_eq!(hits("proto_ok/server/protocol.rs"), vec![]);
}

#[test]
fn frame_registry_drift_is_flagged_per_entry() {
    // Same coverage pass, parameterized for the binary-frame registry:
    // line 10 has no roundtrip tests; line 12's entry has no opcode
    // arm, a missing encode fn, and a test that is not a #[test] fn;
    // line 19 parses an opcode absent from FRAME_COMMANDS.
    let got = hits("frame_bad/server/frame.rs");
    let want: Vec<(&str, usize)> =
        [10, 12, 12, 12, 19].iter().map(|&l| (rule_id::PROTOCOL_COVERAGE, l)).collect();
    assert_eq!(got, want);
    assert_eq!(hits("frame_ok/server/frame.rs"), vec![]);
}

/// `(rule, line)` pairs from a full-tree lint of one fixture subtree —
/// unlike [`hits`] this runs the crate-wide pass (taint, lock order),
/// which per-file linting cannot see.
fn tree_hits(sub: &str) -> Vec<(&'static str, usize)> {
    let report = lint_tree(&fixtures().join(sub), &Allowlist::empty()).expect("subtree lints");
    assert!(report.unused_allow.is_empty());
    report.findings.iter().map(|f| (f.rule, f.line)).collect()
}

#[test]
fn contract_taint_reaches_unmarked_helpers() {
    assert_eq!(tree_hits("taint_bad"), vec![(rule_id::CONTRACT_TAINT, 10)]);
    let report =
        lint_tree(&fixtures().join("taint_bad"), &Allowlist::empty()).expect("subtree lints");
    let msg = &report.findings[0].message;
    assert!(msg.contains("`taint_helper::tb_helper`"), "message: {msg}");
    assert!(msg.contains("via `taint_helper::tb_root` at taint_helper.rs:7"), "message: {msg}");
}

#[test]
fn contract_taint_stops_at_covered_fns_and_audited_leaves() {
    // tk_covered carries its own contract marker, tk_boundary is a
    // `(leaf)`; tk_unwalked behind the leaf is never reached.
    assert_eq!(tree_hits("taint_ok"), vec![]);
}

#[test]
fn opposite_lock_nestings_are_undeclared_and_form_a_cycle() {
    assert_eq!(
        tree_hits("lock_cycle_bad"),
        vec![(rule_id::LOCK_ORDER, 14), (rule_id::LOCK_ORDER, 20), (rule_id::LOCK_ORDER, 20)]
    );
    let report =
        lint_tree(&fixtures().join("lock_cycle_bad"), &Allowlist::empty()).expect("subtree lints");
    let msgs: Vec<&str> = report.findings.iter().map(|f| f.message.as_str()).collect();
    assert!(msgs[0].contains("undeclared lock nesting"), "messages: {msgs:#?}");
    assert!(
        msgs[2].contains(
            "lock-order cycle: two_locks/s.lc_a -> two_locks/s.lc_b -> two_locks/s.lc_a"
        ),
        "messages: {msgs:#?}"
    );
    // both nestings show up as observed lock edges in the graph dump
    assert_eq!(report.graph.lock_edges.len(), 2);
}

#[test]
fn declared_lock_nesting_in_subtree_registry_is_clean() {
    // lock_order_ok/ carries its own analysis/locks.toml sanctioning
    // the one nesting `lo_nest` observes — auto-loaded by lint_tree.
    assert_eq!(tree_hits("lock_order_ok"), vec![]);
}

#[test]
fn blocking_calls_under_held_guards_are_flagged() {
    assert_eq!(
        tree_hits("blocking_bad"),
        vec![(rule_id::BLOCKING_UNDER_LOCK, 9), (rule_id::BLOCKING_UNDER_LOCK, 14)]
    );
    let report =
        lint_tree(&fixtures().join("blocking_bad"), &Allowlist::empty()).expect("subtree lints");
    // line 9 is a direct recv under the guard; line 14 reaches recv
    // interprocedurally through bk_drain.
    assert!(report.findings[0].message.contains("blocking `recv` while holding"));
    assert!(report.findings[1].message.contains("blocking `recv via under_lock::bk_drain`"));
}

#[test]
fn tree_lint_totals_and_allowlist_suppression() {
    // empty allowlist: every violating fixture contributes — per-file
    // rules plus the crate-wide taint/lock pass (which also flags the
    // condvar fixtures' waits as blocking-under-lock).
    let bare = lint_tree(&fixtures(), &Allowlist::empty()).expect("tree lints");
    assert_eq!(bare.findings.len(), 33, "findings: {:#?}", bare.findings);
    assert!(bare.suppressed.is_empty());
    assert!(bare.unused_allow.is_empty());
    assert!(!bare.clean());

    // one narrow entry: exactly the mutex fixture finding disappears
    let allow = Allowlist::parse(
        "inline.toml",
        "[[allow]]\nrule = \"mutex-poison-doc\"\nfile = \"mutex_bad.rs\"\nline = 6\nreason = \"fixture demo\"\n",
    )
    .expect("allowlist parses");
    let report = lint_tree(&fixtures(), &allow).expect("tree lints");
    assert_eq!(report.findings.len(), 32);
    assert_eq!(report.suppressed.len(), 1);
    assert_eq!(report.suppressed[0].0.rule, rule_id::MUTEX_POISON);
    assert_eq!(report.suppressed[0].1, "fixture demo");
    assert!(report.unused_allow.is_empty());

    // an entry that matches nothing fails the build as unused-allow
    let stale = Allowlist::parse(
        "inline.toml",
        "[[allow]]\nrule = \"unsafe-safety\"\nfile = \"no_such_file.rs\"\nreason = \"stale\"\n",
    )
    .expect("allowlist parses");
    let report = lint_tree(&fixtures(), &stale).expect("tree lints");
    assert_eq!(report.unused_allow.len(), 1);
    assert_eq!(report.unused_allow[0].rule, rule_id::UNUSED_ALLOW);
    assert!(!report.clean());
}

#[test]
fn jsonl_output_is_reason_tagged_and_parseable() {
    let allow = Allowlist::parse(
        "inline.toml",
        "[[allow]]\nrule = \"mutex-poison-doc\"\nfile = \"mutex_bad.rs\"\nreason = \"fixture demo\"\n",
    )
    .expect("allowlist parses");
    let report = lint_tree(&fixtures(), &allow).expect("tree lints");
    let log = EventLog::capture();
    emit_jsonl(&report, &log);
    let lines = log.captured();
    assert_eq!(lines.len(), report.findings.len() + report.suppressed.len() + 1);
    assert_eq!(log.count("lint-finding"), report.findings.len());
    assert_eq!(log.count("lint-allowed"), 1);
    assert_eq!(log.count("lint-summary"), 1);
    for line in &lines {
        assert!(line.starts_with("{\"reason\":\"lint-"), "bad prefix: {line}");
        let v = Json::parse(line).unwrap_or_else(|e| panic!("bad JSONL {line}: {e:?}"));
        assert!(v.get("reason").and_then(Json::as_str).is_some());
    }
    let finding = Json::parse(&lines[0]).expect("finding line parses");
    assert!(finding.get("rule").and_then(Json::as_str).is_some());
    assert!(finding.get("file").and_then(Json::as_str).is_some());
    assert!(finding.get("line").and_then(Json::as_usize).is_some());
    let summary = Json::parse(lines.last().expect("summary line")).expect("summary parses");
    assert_eq!(
        summary.get("failing").and_then(Json::as_usize),
        Some(report.findings.len() + report.unused_allow.len())
    );
}

/// The acceptance gate: the repo's own `src/` tree is lint-clean at
/// HEAD under the checked-in allowlist.
#[test]
fn repo_src_is_clean_under_checked_in_allowlist() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let allow = Allowlist::load(&root.join("analysis/allow.toml")).expect("allow.toml parses");
    let report: LintReport = lint_tree(&root, &allow).expect("src lints");
    assert!(
        report.clean(),
        "src/ has {} lint finding(s):\n{:#?}\nunused allow entries: {:#?}",
        report.findings.len(),
        report.findings,
        report.unused_allow
    );
    assert!(report.files > 40, "walk looks truncated: {} files", report.files);
}

/// End-to-end sweep the CI gate runs: `src/` plus the aux trees
/// (`benches/`, `examples/`) under one allowlist, with the call/lock
/// graphs dumped as JSONL (`--graph-out`) and spot-checked for a known
/// engine -> kernel edge.
#[test]
fn repo_sweep_with_aux_trees_emits_parseable_graph_jsonl() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let root = manifest.join("src");
    let allow = Allowlist::load(&root.join("analysis/allow.toml")).expect("allow.toml parses");
    // examples/ lives one level above the crate (see Cargo.toml's
    // `path = "../examples/..."` entries)
    let aux = vec![
        manifest.join("benches"),
        manifest.parent().expect("crate has a parent dir").join("examples"),
    ];
    let report = lint_tree_with_aux(&root, &aux, &allow).expect("sweep lints");
    assert!(
        report.clean(),
        "sweep has {} finding(s):\n{:#?}\nunused allow entries: {:#?}",
        report.findings.len(),
        report.findings,
        report.unused_allow
    );

    assert!(report.graph.fns > 100, "call graph looks truncated: {} fns", report.graph.fns);
    assert!(
        report.graph.call_edges.iter().any(|(caller, callee, _, _)| {
            caller.starts_with("cluster::engine") && callee.starts_with("kernel::")
        }),
        "no engine -> kernel call edge among {} edges",
        report.graph.call_edges.len()
    );
    // the one sanctioned nesting in analysis/locks.toml is observed
    assert!(
        report
            .graph
            .lock_edges
            .iter()
            .any(|(first, then, ..)| first.starts_with("coordinator::remote")
                && then.starts_with("telemetry::events")),
        "sanctioned remote -> events nesting not observed: {:#?}",
        report.graph.lock_edges
    );

    let log = EventLog::capture();
    emit_graph_jsonl(&report, &log);
    let lines = log.captured();
    assert_eq!(
        lines.len(),
        report.graph.call_edges.len() + report.graph.lock_edges.len() + 1
    );
    assert_eq!(log.count("graph-call-edge"), report.graph.call_edges.len());
    assert_eq!(log.count("graph-lock-edge"), report.graph.lock_edges.len());
    assert_eq!(log.count("graph-summary"), 1);
    for line in &lines {
        assert!(line.starts_with("{\"reason\":\"graph-"), "bad prefix: {line}");
        let v = Json::parse(line).unwrap_or_else(|e| panic!("bad JSONL {line}: {e:?}"));
        assert!(v.get("reason").and_then(Json::as_str).is_some());
    }
    let edge = Json::parse(&lines[0]).expect("edge line parses");
    assert!(edge.get("caller").and_then(Json::as_str).is_some());
    assert!(edge.get("callee").and_then(Json::as_str).is_some());
    assert!(edge.get("line").and_then(Json::as_usize).is_some());
    let summary = Json::parse(lines.last().expect("summary line")).expect("summary parses");
    assert_eq!(
        summary.get("call_edges").and_then(Json::as_usize),
        Some(report.graph.call_edges.len())
    );
    assert_eq!(summary.get("fns").and_then(Json::as_usize), Some(report.graph.fns));
}
