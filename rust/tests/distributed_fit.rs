//! Fault injection for the distributed sharded fit.
//!
//! The invariant every scenario pins: a distributed fit — through real
//! workers, dead addresses, hung sockets, malformed replies,
//! quarantines, and total fleet loss — produces the **bit-identical**
//! result of a single-node fit.  Fault tolerance is allowed to cost
//! wall time, never bits.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener};
use std::time::Duration;

use parsample::coordinator::batcher::strided_init;
use parsample::coordinator::remote::{probe_worker, RemoteConfig};
use parsample::coordinator::SchedulerConfig;
use parsample::data::synthetic::{make_blobs, BlobSpec};
use parsample::data::Dataset;
use parsample::pipeline::{PipelineConfig, PipelineResult, SubclusterPipeline};
use parsample::runtime::{Backend, DeviceBatch, NativeBackend};
use parsample::server::{Client, Server};
use parsample::telemetry::EventLog;
use parsample::util::json::Json;

fn blobs(m: usize, k: usize, seed: u64) -> Dataset {
    make_blobs(&BlobSpec {
        num_points: m,
        num_clusters: k,
        dims: 2,
        std: 0.05,
        extent: 10.0,
        seed,
    })
    .unwrap()
}

fn pipeline_cfg(k: usize, remote: Option<RemoteConfig>) -> PipelineConfig {
    let mut b = PipelineConfig::builder()
        .final_k(k)
        .num_groups(6)
        .compression(5.0)
        .workers(4)
        .seed(0);
    if let Some(r) = remote {
        b = b.remote(r);
    }
    b.build().unwrap()
}

/// Aggressive-but-sane fault-tolerance knobs for tests: short
/// deadlines, tiny backoff, captured events.
fn remote_cfg(workers: Vec<String>) -> RemoteConfig {
    RemoteConfig {
        workers,
        connect_timeout: Duration::from_millis(500),
        read_timeout: Duration::from_secs(5),
        write_timeout: Duration::from_secs(5),
        max_attempts: 3,
        backoff_base: Duration::from_millis(5),
        backoff_cap: Duration::from_millis(20),
        quarantine_after: 2,
        probe_interval: Duration::from_millis(20),
        events: EventLog::capture(),
    }
}

fn start_worker() -> Server {
    Server::start("127.0.0.1:0", SchedulerConfig::default()).expect("worker start")
}

/// An address that refuses connections: bind-then-drop guarantees the
/// port was free a moment ago.
fn dead_addr() -> String {
    let l = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = l.local_addr().unwrap();
    drop(l);
    format!("{addr}")
}

/// A listener that accepts connections and then never responds — the
/// read deadline is the only way out.
fn spawn_black_hole() -> SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        let mut held = Vec::new();
        for stream in listener.incoming() {
            match stream {
                Ok(s) => held.push(s), // keep it open, say nothing
                Err(_) => break,
            }
        }
    });
    addr
}

/// A fake worker whose reply policy is a pure function of the request
/// line (`None` = slam the connection shut mid-exchange).
fn spawn_fake_worker(behavior: fn(&str) -> Option<String>) -> SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(stream) = stream else { break };
            std::thread::spawn(move || {
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut writer = stream;
                let mut line = String::new();
                loop {
                    line.clear();
                    match reader.read_line(&mut line) {
                        Ok(0) | Err(_) => return,
                        Ok(_) => {}
                    }
                    match behavior(line.trim_end()) {
                        Some(reply) => {
                            if writer
                                .write_all(reply.as_bytes())
                                .and_then(|()| writer.write_all(b"\n"))
                                .and_then(|()| writer.flush())
                                .is_err()
                            {
                                return;
                            }
                        }
                        None => return,
                    }
                }
            });
        }
    });
    addr
}

fn assert_bit_identical(local: &PipelineResult, dist: &PipelineResult) {
    assert_eq!(local.labels, dist.labels, "labels diverged");
    assert_eq!(local.counts, dist.counts, "counts diverged");
    assert_eq!(local.centers, dist.centers, "centers diverged (bitwise)");
    assert_eq!(
        local.inertia.to_bits(),
        dist.inertia.to_bits(),
        "inertia diverged (bitwise): {} vs {}",
        local.inertia,
        dist.inertia
    );
}

/// Run the same data through a local fit and a remote fit and demand
/// identical bits; returns the remote config's captured events.
fn parity_run(data: &Dataset, k: usize, remote: RemoteConfig) -> Vec<String> {
    let events = remote.events.clone();
    let local = SubclusterPipeline::new(pipeline_cfg(k, None)).run(data).unwrap();
    let dist = SubclusterPipeline::new(pipeline_cfg(k, Some(remote)))
        .run(data)
        .unwrap();
    assert_bit_identical(&local, &dist);
    events.captured()
}

#[test]
fn two_real_workers_bit_identical() {
    let mut w1 = start_worker();
    let mut w2 = start_worker();
    let remote = remote_cfg(vec![format!("{}", w1.addr()), format!("{}", w2.addr())]);
    let events = remote.events.clone();
    let data = blobs(900, 3, 7);
    parity_run(&data, 3, remote);
    // the healthy fleet did all the work: no retries, no fallbacks
    assert!(events.count("dispatch") >= 2, "both workers dispatched");
    assert_eq!(events.count("retry"), 0);
    assert_eq!(events.count("fallback"), 0);
    assert_eq!(events.count("quarantine"), 0);
    assert_eq!(events.count("merge"), 1);
    w1.shutdown();
    w2.shutdown();
}

#[test]
fn dead_address_in_fleet_recovers_bit_identical() {
    let mut w1 = start_worker();
    let remote = remote_cfg(vec![dead_addr(), format!("{}", w1.addr())]);
    let events = remote.events.clone();
    let data = blobs(600, 3, 11);
    parity_run(&data, 3, remote);
    // the dead worker's pinned groups were retried or fell back, and
    // it was quarantined after consecutive connection refusals
    assert!(events.count("retry") + events.count("fallback") >= 1);
    assert_eq!(events.count("quarantine"), 1);
    w1.shutdown();
}

#[test]
fn hung_worker_hits_read_deadline_bit_identical() {
    let mut w1 = start_worker();
    let hole = spawn_black_hole();
    let mut remote = remote_cfg(vec![format!("{hole}"), format!("{}", w1.addr())]);
    // tight reply deadline so the hang resolves in test time
    remote.read_timeout = Duration::from_millis(300);
    remote.max_attempts = 2;
    remote.quarantine_after = 1;
    let events = remote.events.clone();
    let data = blobs(600, 3, 13);
    parity_run(&data, 3, remote);
    // every failure reason names the read, proving the deadline (not a
    // connect error) fired
    let failures: Vec<String> = events
        .captured()
        .into_iter()
        .filter(|l| l.contains("\"reason\":\"retry\"") || l.contains("\"reason\":\"fallback\""))
        .collect();
    assert!(!failures.is_empty(), "the black hole must have failed something");
    assert!(
        failures.iter().all(|l| l.contains("read")),
        "expected read-deadline failures, got: {failures:?}"
    );
    assert_eq!(events.count("quarantine"), 1);
    w1.shutdown();
}

#[test]
fn malformed_reply_is_retried_bit_identical() {
    let mut w1 = start_worker();
    let garbage = spawn_fake_worker(|_| Some("this is not json".to_string()));
    let mut remote = remote_cfg(vec![format!("{garbage}"), format!("{}", w1.addr())]);
    remote.quarantine_after = 1;
    let events = remote.events.clone();
    let data = blobs(600, 3, 17);
    parity_run(&data, 3, remote);
    assert!(events.count("retry") + events.count("fallback") >= 1);
    assert_eq!(events.count("quarantine"), 1);
    w1.shutdown();
}

#[test]
fn truncated_reply_is_retried_bit_identical() {
    let mut w1 = start_worker();
    // shaped like a reply but missing everything the merge needs
    let stub = spawn_fake_worker(|_| Some("{\"ok\":true,\"id\":0}".to_string()));
    let mut remote = remote_cfg(vec![format!("{stub}"), format!("{}", w1.addr())]);
    remote.quarantine_after = 1;
    let data = blobs(600, 3, 19);
    parity_run(&data, 3, remote);
    // a connection slammed mid-exchange is also just a failed attempt
    let slam = spawn_fake_worker(|_| None);
    let mut remote = remote_cfg(vec![format!("{slam}"), format!("{}", w1.addr())]);
    remote.quarantine_after = 1;
    parity_run(&data, 3, remote);
    w1.shutdown();
}

#[test]
fn total_fleet_loss_falls_back_bit_identical() {
    let mut remote = remote_cfg(vec![dead_addr(), dead_addr()]);
    remote.max_attempts = 1;
    remote.quarantine_after = 1;
    let events = remote.events.clone();
    let data = blobs(600, 3, 23);
    parity_run(&data, 3, remote);
    // every group resolved locally; both workers quarantined
    assert_eq!(events.count("quarantine"), 2);
    assert!(events.count("fallback") >= 2, "all groups fell back");
    let merge = events
        .captured()
        .into_iter()
        .find(|l| l.contains("\"reason\":\"merge\""))
        .expect("merge event");
    assert!(merge.contains("\"remote\":0"), "no group resolved remotely: {merge}");
}

#[test]
fn quarantined_worker_is_probed_and_readmitted() {
    // answers pings (so the probe succeeds) but botches every
    // fit_group: it quarantines, gets readmitted, fails again, forever
    // — while the real worker grinds through the actual work
    let flaky = spawn_fake_worker(|line| {
        if line.contains("\"cmd\":\"ping\"") {
            Some("{\"pong\":true}".to_string())
        } else {
            Some("{\"ok\":true}".to_string())
        }
    });
    let mut w1 = start_worker();
    let mut remote = remote_cfg(vec![format!("{flaky}"), format!("{}", w1.addr())]);
    remote.quarantine_after = 1;
    remote.probe_interval = Duration::from_millis(1);
    let events = remote.events.clone();
    // enough work per group that the real worker is still busy when
    // the flaky worker's first probe fires
    let data = blobs(12_000, 4, 29);
    parity_run(&data, 4, remote);
    assert!(events.count("quarantine") >= 1);
    assert!(
        events.count("readmit") >= 1,
        "probe should have readmitted the ping-answering worker: {:?}",
        events.captured()
    );
    w1.shutdown();
}

#[test]
fn probe_worker_tells_live_from_dead() {
    let mut w1 = start_worker();
    let cfg = remote_cfg(vec![]);
    assert!(probe_worker(&format!("{}", w1.addr()), &cfg));
    assert!(!probe_worker(&dead_addr(), &cfg));
    w1.shutdown();
    // a shut-down worker stops probing true
    assert!(!probe_worker(&format!("{}", w1.addr()), &cfg));
}

/// The wire primitive itself: a `fit_group` answered by a real server
/// carries the bit-exact centers/counts/inertia of a local
/// `NativeBackend` run on the same rows — the per-group contract the
/// whole distributed parity story reduces to.
#[test]
fn wire_fit_group_matches_local_backend_bitwise() {
    let mut server = start_worker();
    let data = blobs(240, 3, 31);
    let (n, d, k, iters) = (data.len(), data.dims(), 12, 10);
    let points = data.as_slice().to_vec();

    // local reference: the exact batch the server must reconstruct
    let batch = DeviceBatch {
        b: 1,
        n,
        d,
        k,
        iters,
        points: points.clone(),
        weights: vec![1.0; n],
        init: strided_init(&points, n, k, d),
    };
    let local = NativeBackend::serial().run_batch(&batch).unwrap();

    let rows: Vec<String> = points
        .chunks(d)
        .map(|r| {
            let xs: Vec<String> = r.iter().map(|x| format!("{x}")).collect();
            format!("[{}]", xs.join(","))
        })
        .collect();
    let req = format!(
        "{{\"cmd\":\"fit_group\",\"id\":7,\"points\":[{}],\"k\":{k},\"iters\":{iters}}}",
        rows.join(",")
    );
    let mut client = Client::connect(server.addr()).unwrap();
    let v = Json::parse(&client.call(&req).unwrap()).unwrap();
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v:?}");
    assert_eq!(v.get("id").unwrap().as_usize(), Some(7));

    let wire_centers: Vec<f32> = v
        .get("centers")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .flat_map(|row| row.as_arr().unwrap().iter().map(|x| x.as_f64().unwrap() as f32))
        .collect();
    let wire_counts: Vec<f32> = v
        .get("counts")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as f32)
        .collect();
    let wire_inertia = v.get("inertia").unwrap().as_f64().unwrap() as f32;

    assert_eq!(wire_centers, local.centers, "centers diverged over the wire");
    assert_eq!(wire_counts, local.counts, "counts diverged over the wire");
    assert_eq!(
        wire_inertia.to_bits(),
        local.inertia[0].to_bits(),
        "inertia diverged over the wire"
    );
    drop(client);
    server.shutdown();
}

/// Streaming fits ride the same seam: `fit_source` with a remote fleet
/// is bit-identical to the resident local fit on the same bytes.
#[test]
fn streaming_fit_uses_the_fleet_bit_identical() {
    use parsample::data::source::SliceSource;
    use parsample::model::ClusterModel;

    let mut w1 = start_worker();
    let data = blobs(600, 3, 37);
    let local = SubclusterPipeline::new(pipeline_cfg(3, None)).fit(&data).unwrap();

    let remote = remote_cfg(vec![format!("{}", w1.addr())]);
    let events = remote.events.clone();
    let dist = SubclusterPipeline::new(pipeline_cfg(3, Some(remote)))
        .fit_source(&mut SliceSource::of(&data))
        .unwrap();
    assert_eq!(local.centers(), dist.centers(), "streamed remote fit diverged");
    assert!(events.count("dispatch") >= 1, "the fleet saw the streamed groups");
    w1.shutdown();
}
