//! Integration: the event-driven serving subsystem under concurrency —
//! JSON-lines and binary-frame clients interleaved on one listener,
//! predict micro-batch coalescing on and off, reactor and legacy
//! loops, with every predict reply bit-identical to a local
//! `FittedModel::predict_batch`, plus frame rejection, protocol
//! pinning, idle-client shutdown, and serving counters.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Barrier};

use parsample::cluster::EngineOpts;
use parsample::data::synthetic::{make_blobs, BlobSpec};
use parsample::model::{ClusterModel, FittedModel, KMeans};
use parsample::server::frame::{self, FrameClient, OP_ERROR, OP_PING, OP_PONG, OP_PREDICT};
use parsample::server::protocol::encode_prediction;
use parsample::server::{Client, ProtocolMode, Server, ServerConfig};
use parsample::telemetry::EventLog;
use parsample::util::json::Json;

const DIMS: usize = 3;

/// Deterministic fitted model + the points it was trained on.
fn fitted() -> (FittedModel, Vec<f32>) {
    let data = make_blobs(&BlobSpec {
        num_points: 600,
        num_clusters: 4,
        dims: DIMS,
        std: 0.05,
        extent: 10.0,
        seed: 7,
    })
    .expect("blobs");
    let model = KMeans::new(4).fit(&data).expect("fit");
    let pts = data.as_slice().to_vec();
    (model, pts)
}

/// A server preloaded with the model as "prod", plus the engine opts
/// its predict path uses (for bit-exact local ground truth).
fn serve(
    model: &FittedModel,
    reactor: bool,
    coalesce_us: u64,
    protocol: ProtocolMode,
    events: Arc<EventLog>,
) -> (Server, EngineOpts) {
    let cfg = ServerConfig {
        reactor,
        coalesce_us,
        protocol,
        events,
        preload: vec![("prod".to_string(), model.clone())],
        ..ServerConfig::default()
    };
    let engine = cfg.engine;
    let server = Server::start_with("127.0.0.1:0", cfg).expect("server start");
    (server, engine)
}

fn points_json(points: &[f32]) -> String {
    let rows: Vec<String> = points
        .chunks(DIMS)
        .map(|r| {
            let xs: Vec<String> = r.iter().map(|x| format!("{x}")).collect();
            format!("[{}]", xs.join(","))
        })
        .collect();
    format!("[{}]", rows.join(","))
}

/// The heart of the PR's acceptance criterion: N simultaneous clients,
/// half JSON lines and half binary frames, against {reactor, legacy} ×
/// {coalescing off, on} — every reply must carry the exact bits a
/// local `predict_batch` produces (JSON compared as the whole response
/// line against the canonical encoder, binary as raw label/count/
/// inertia bits).
#[test]
fn mixed_protocol_clients_predict_bit_identically() {
    let (model, pts) = fitted();
    for (reactor, coalesce_us) in [(true, 0), (true, 1500), (false, 0)] {
        let (server, engine) =
            serve(&model, reactor, coalesce_us, ProtocolMode::Auto, EventLog::off());
        let addr = server.addr();
        // odd row counts so request boundaries never align with the
        // engine's reduction blocks
        let chunks: Vec<&[f32]> = vec![
            &pts[..7 * DIMS],
            &pts[7 * DIMS..20 * DIMS],
            &pts[20 * DIMS..49 * DIMS],
            &pts[49 * DIMS..110 * DIMS],
        ];
        std::thread::scope(|s| {
            for t in 0..6 {
                let model = &model;
                let chunks = &chunks;
                s.spawn(move || {
                    if t % 2 == 0 {
                        let mut client = FrameClient::connect(addr).expect("connect");
                        for chunk in chunks.iter().cycle().skip(t).take(8) {
                            let (labels, counts, inertia) =
                                client.predict("prod", chunk, DIMS).expect("predict");
                            let want = model.predict_batch_with(chunk, engine).expect("local");
                            assert_eq!(labels, want.labels);
                            assert_eq!(counts, want.counts);
                            assert_eq!(
                                inertia.to_bits(),
                                want.inertia.to_bits(),
                                "binary inertia drifted (reactor={reactor}, coalesce={coalesce_us})"
                            );
                        }
                    } else {
                        let mut client = Client::connect(addr).expect("connect");
                        for chunk in chunks.iter().cycle().skip(t).take(8) {
                            let req = format!(
                                "{{\"cmd\":\"predict\",\"name\":\"prod\",\"points\":{}}}",
                                points_json(chunk)
                            );
                            let got = client.call(&req).expect("predict");
                            let want = model.predict_batch_with(chunk, engine).expect("local");
                            assert_eq!(
                                got,
                                encode_prediction("prod", &want),
                                "JSON reply drifted (reactor={reactor}, coalesce={coalesce_us})"
                            );
                        }
                    }
                });
            }
        });
    }
}

/// Coalescing accounting: simultaneous predicts released by a barrier
/// all arrive inside one window; whatever grouping the reactor
/// achieves, the counters must add up and every reply must still be
/// exact.
#[test]
fn coalesced_predict_counters_add_up() {
    let (model, pts) = fitted();
    let (server, engine) =
        serve(&model, true, 5_000, ProtocolMode::Auto, EventLog::off());
    let addr = server.addr();
    let n = 8;
    let barrier = Arc::new(Barrier::new(n));
    std::thread::scope(|s| {
        for t in 0..n {
            let barrier = Arc::clone(&barrier);
            let model = &model;
            let chunk = &pts[t * 5 * DIMS..(t * 5 + 5) * DIMS];
            s.spawn(move || {
                let mut client = FrameClient::connect(addr).expect("connect");
                barrier.wait();
                let (labels, counts, inertia) =
                    client.predict("prod", chunk, DIMS).expect("predict");
                let want = model.predict_batch_with(chunk, engine).expect("local");
                assert_eq!(labels, want.labels);
                assert_eq!(counts, want.counts);
                assert_eq!(inertia.to_bits(), want.inertia.to_bits());
            });
        }
    });
    let stats = server.stats();
    assert_eq!(stats.batched_predicts.load(Ordering::Relaxed), n as u64);
    let batches = stats.predict_batches.load(Ordering::Relaxed);
    assert!((1..=n as u64).contains(&batches), "batches={batches}");
    let max_batch = stats.max_batch.load(Ordering::Relaxed);
    assert!((1..=n as u64).contains(&max_batch), "max_batch={max_batch}");
}

/// Read one frame off a raw stream (test-side decoder).
fn read_frame(stream: &mut TcpStream, buf: &mut Vec<u8>) -> Option<(u8, Vec<u8>)> {
    let mut tmp = [0u8; 4096];
    loop {
        if let Some((op, body, consumed)) = frame::take_frame(buf).expect("client-side frame") {
            buf.drain(..consumed);
            return Some((op, body));
        }
        let n = stream.read(&mut tmp).expect("read");
        if n == 0 {
            return None;
        }
        buf.extend_from_slice(&tmp[..n]);
    }
}

fn read_until_eof(stream: &mut TcpStream) -> Vec<u8> {
    let mut out = Vec::new();
    let _ = stream.read_to_end(&mut out);
    out
}

/// Frame-level rejection on both serving loops: an undecodable body
/// is answered and the connection survives; an unresyncable length
/// header (zero-length, oversized) is answered and the connection is
/// dropped; a bad preamble is answered in JSON and dropped.
#[test]
fn malformed_truncated_and_oversized_frames_are_rejected() {
    let (model, _) = fitted();
    for reactor in [true, false] {
        let (server, _) = serve(&model, reactor, 0, ProtocolMode::Auto, EventLog::off());
        let addr = server.addr();

        // malformed predict body: error reply, stream still serves
        let mut s = TcpStream::connect(addr).expect("connect");
        s.write_all(&frame::FRAME_MAGIC).expect("magic");
        s.write_all(&frame::encode_frame(OP_PREDICT, &[0xff])).expect("bad predict");
        let mut buf = Vec::new();
        let (op, body) = read_frame(&mut s, &mut buf).expect("reply");
        assert_eq!(op, OP_ERROR, "reactor={reactor}");
        assert!(
            String::from_utf8_lossy(&body).contains("malformed predict frame"),
            "reactor={reactor}: {}",
            String::from_utf8_lossy(&body)
        );
        // unknown opcode: also answered, also survivable
        s.write_all(&frame::encode_frame(0x55, &[])).expect("unknown opcode");
        let (op, body) = read_frame(&mut s, &mut buf).expect("reply");
        assert_eq!(op, OP_ERROR);
        assert!(String::from_utf8_lossy(&body).contains("unknown request opcode"));
        s.write_all(&frame::encode_frame(OP_PING, &[])).expect("ping");
        let (op, _) = read_frame(&mut s, &mut buf).expect("reply");
        assert_eq!(op, OP_PONG, "connection must survive decode errors");
        drop(s);

        // zero-length frame: answered, then dropped
        let mut s = TcpStream::connect(addr).expect("connect");
        s.write_all(&frame::FRAME_MAGIC).expect("magic");
        s.write_all(&0u32.to_le_bytes()).expect("zero len");
        let mut buf = Vec::new();
        let (op, body) = read_frame(&mut s, &mut buf).expect("reply");
        assert_eq!(op, OP_ERROR);
        assert!(String::from_utf8_lossy(&body).contains("zero-length frame"));
        assert!(read_frame(&mut s, &mut buf).is_none(), "unresyncable: must close");

        // oversized frame: answered, then dropped — nothing close to
        // the claimed payload is ever read
        let mut s = TcpStream::connect(addr).expect("connect");
        s.write_all(&frame::FRAME_MAGIC).expect("magic");
        s.write_all(&((frame::MAX_FRAME_BYTES + 1) as u32).to_le_bytes()).expect("len");
        let mut buf = Vec::new();
        let (op, body) = read_frame(&mut s, &mut buf).expect("reply");
        assert_eq!(op, OP_ERROR);
        assert!(String::from_utf8_lossy(&body).contains("exceeds"));
        assert!(read_frame(&mut s, &mut buf).is_none());

        // bad preamble: JSON error (the peer never proved it speaks
        // frames), then dropped
        let mut s = TcpStream::connect(addr).expect("connect");
        s.write_all(b"PSXX").expect("bad magic");
        let reply = read_until_eof(&mut s);
        let text = String::from_utf8_lossy(&reply);
        let line = text.lines().next().expect("one reply line");
        let v = Json::parse(line).expect("json error");
        assert_eq!(v.get("ok"), Some(&Json::Bool(false)));
        assert!(v.get("error").expect("error").as_str().expect("str").contains("PSF1"));
    }
}

/// `--protocol` pins one wire format: a binary-only listener rejects
/// JSON greetings with an error frame; a JSON-only listener treats
/// the magic as a (bad) request line.
#[test]
fn pinned_protocols_reject_the_other_format() {
    let (model, _) = fitted();
    for reactor in [true, false] {
        let (server, _) = serve(&model, reactor, 0, ProtocolMode::Binary, EventLog::off());
        let mut s = TcpStream::connect(server.addr()).expect("connect");
        s.write_all(b"{\"cmd\":\"ping\"}\n").expect("json hello");
        let mut buf = Vec::new();
        let (op, body) = read_frame(&mut s, &mut buf).expect("reply");
        assert_eq!(op, OP_ERROR, "reactor={reactor}");
        assert!(String::from_utf8_lossy(&body).contains("PSF1"));

        let (server, _) = serve(&model, reactor, 0, ProtocolMode::JsonLines, EventLog::off());
        let mut s = TcpStream::connect(server.addr()).expect("connect");
        s.write_all(b"PSF1\n").expect("magic as a line");
        let mut reply = Vec::new();
        let mut tmp = [0u8; 1024];
        while !reply.contains(&b'\n') {
            let n = s.read(&mut tmp).expect("read");
            assert!(n > 0, "server closed without answering");
            reply.extend_from_slice(&tmp[..n]);
        }
        let text = String::from_utf8_lossy(&reply);
        let v = Json::parse(text.lines().next().expect("line")).expect("json");
        assert_eq!(v.get("ok"), Some(&Json::Bool(false)), "reactor={reactor}");
    }
}

/// Reactor shutdown must not wait on idle connections — including a
/// binary client that negotiated and then went silent.
#[test]
fn idle_clients_do_not_stall_reactor_shutdown() {
    let (model, _) = fitted();
    let (mut server, _) = serve(&model, true, 0, ProtocolMode::Auto, EventLog::off());
    let addr = server.addr();
    let mut idle_json = Client::connect(addr).expect("connect");
    let _ = idle_json.call("{\"cmd\":\"ping\"}").expect("ping");
    let mut idle_binary = FrameClient::connect(addr).expect("connect");
    idle_binary.ping().expect("ping");
    let t0 = std::time::Instant::now();
    server.shutdown();
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(5),
        "shutdown took {:?} with idle connections open",
        t0.elapsed()
    );
    assert!(idle_binary.ping().is_err(), "idle binary connection must be dead");
}

/// Satellite: serving counters ride the existing `stats` command, and
/// the reason-tagged event stream records accepts, batches, and
/// closes.
#[test]
fn stats_and_events_surface_serving_counters() {
    let (model, pts) = fitted();
    let events = EventLog::capture();
    let (server, _) = serve(&model, true, 0, ProtocolMode::Auto, Arc::clone(&events));
    let addr = server.addr();

    let mut binary = FrameClient::connect(addr).expect("connect");
    binary.ping().expect("ping");
    let _ = binary.predict("prod", &pts[..10 * DIMS], DIMS).expect("predict");
    drop(binary);

    let mut json = Client::connect(addr).expect("connect");
    let req = format!(
        "{{\"cmd\":\"predict\",\"name\":\"prod\",\"points\":{}}}",
        points_json(&pts[..4 * DIMS])
    );
    let v = Json::parse(&json.call(&req).expect("predict")).expect("json");
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)));

    let stats = Json::parse(&json.call("{\"cmd\":\"stats\"}").expect("stats")).expect("json");
    let field = |k: &str| stats.get(k).and_then(Json::as_usize).unwrap_or_else(|| {
        panic!("stats missing {k}: {stats:?}")
    });
    assert!(field("connections_accepted") >= 2);
    assert!(field("connections_open") >= 1);
    assert!(field("frames_decoded") >= 2, "ping + predict frames");
    assert!(field("predict_batches") >= 2);
    assert!(field("batched_predicts") >= 2);
    assert!(field("max_batch") >= 1);
    // in-process view agrees with the wire view
    assert_eq!(
        server.stats().frames_decoded.load(Ordering::Relaxed) as usize,
        field("frames_decoded")
    );

    // the dropped binary client's close is swept asynchronously
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    while events.count("close") == 0 && std::time::Instant::now() < deadline {
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert!(events.count("accept") >= 2, "events: {:?}", events.captured());
    assert!(events.count("batch") >= 2, "events: {:?}", events.captured());
    assert!(events.count("close") >= 1, "events: {:?}", events.captured());
}
