//! Integration: the PJRT backend loads the AOT artifacts, executes
//! them, and agrees with the native mirror (the CORE cross-layer
//! correctness signal of the whole three-layer stack).
//!
//! Requires `make artifacts` to have been run (skips otherwise).

use parsample::coordinator::batcher::{local_k, Batcher};
use parsample::data::synthetic::{make_blobs, BlobSpec};
use parsample::runtime::{Backend, DeviceBatch, NativeBackend, PjrtBackend};
use parsample::util::rng::Pcg32;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
                return;
            }
        }
    };
}

/// Build a bucket-shaped batch with `real_n` real points per slot.
#[allow(clippy::too_many_arguments)]
fn padded_batch(
    b: usize,
    n: usize,
    d: usize,
    k: usize,
    iters: usize,
    real_n: usize,
    real_d: usize,
    real_k: usize,
    seed: u64,
) -> DeviceBatch {
    let mut rng = Pcg32::seeded(seed);
    let mut points = vec![0.0f32; b * n * d];
    let mut weights = vec![0.0f32; b * n];
    let mut init = vec![1e12f32; b * k * d];
    for slot in 0..b {
        for i in 0..real_n {
            for j in 0..real_d {
                points[slot * n * d + i * d + j] = rng.uniform(0.0, 1.0);
            }
            weights[slot * n + i] = 1.0;
        }
        for c in 0..real_k {
            for j in 0..d {
                init[slot * k * d + c * d + j] = if j < real_d {
                    points[slot * n * d + c * d + j]
                } else {
                    0.0
                };
            }
        }
    }
    DeviceBatch { b, n, d, k, iters, points, weights, init }
}

fn assert_outputs_match(
    pjrt: &parsample::runtime::DeviceOutput,
    native: &parsample::runtime::DeviceOutput,
    batch: &DeviceBatch,
    tol: f32,
) {
    assert_eq!(pjrt.centers.len(), native.centers.len());
    for (i, (a, b)) in pjrt.centers.iter().zip(&native.centers).enumerate() {
        assert!(
            (a - b).abs() <= tol * (1.0 + a.abs()),
            "center[{i}]: pjrt {a} vs native {b}"
        );
    }
    // labels compared on real rows only (native skips pad rows)
    for slot in 0..batch.b {
        for i in 0..batch.n {
            if batch.weights[slot * batch.n + i] != 0.0 {
                assert_eq!(
                    pjrt.labels[slot * batch.n + i],
                    native.labels[slot * batch.n + i],
                    "label mismatch at slot {slot} row {i}"
                );
            }
        }
    }
    for (a, b) in pjrt.counts.iter().zip(&native.counts) {
        assert!((a - b).abs() < 0.5, "counts: {a} vs {b}");
    }
    for (a, b) in pjrt.inertia.iter().zip(&native.inertia) {
        assert!((a - b).abs() <= tol * 10.0 * (1.0 + a.abs()), "inertia: {a} vs {b}");
    }
}

#[test]
fn manifest_loads_and_buckets_compile() {
    let dir = require_artifacts!();
    let backend = PjrtBackend::load(&dir).unwrap();
    assert_eq!(backend.platform().to_lowercase(), "cpu");
    assert!(backend.manifest().buckets.len() >= 5);
    // warm the smallest bucket explicitly
    backend.warm("local_s").unwrap();
    assert!(backend.warmed().contains(&"local_s".to_string()));
}

#[test]
fn pjrt_matches_native_on_local_s() {
    let dir = require_artifacts!();
    let backend = PjrtBackend::load(&dir).unwrap();
    let spec = backend.manifest().by_name("local_s").unwrap().clone();
    let batch = padded_batch(
        spec.b, spec.n, spec.d, spec.k, spec.iters, 40, 4, 8, // 40 real pts, d=4, k=8
        7,
    );
    let out_pjrt = backend.run_in_bucket("local_s", &batch).unwrap();
    let out_native = NativeBackend::serial().run_batch(&batch).unwrap();
    assert_outputs_match(&out_pjrt, &out_native, &batch, 1e-4);
}

#[test]
fn pjrt_matches_native_on_local_m_partial_batch() {
    let dir = require_artifacts!();
    let backend = PjrtBackend::load(&dir).unwrap();
    let spec = backend.manifest().by_name("local_m").unwrap().clone();
    // only 3 of the B slots carry real data; rest fully padded
    let mut batch = padded_batch(
        spec.b, spec.n, spec.d, spec.k, spec.iters, 300, 2, 60, 11,
    );
    for slot in 3..spec.b {
        for i in 0..spec.n {
            batch.weights[slot * spec.n + i] = 0.0;
        }
    }
    let out_pjrt = backend.run_in_bucket("local_m", &batch).unwrap();
    let out_native = NativeBackend::new(4).run_batch(&batch).unwrap();
    assert_outputs_match(&out_pjrt, &out_native, &batch, 1e-3);
    // fully-padded slots contribute nothing
    for slot in 3..spec.b {
        assert_eq!(out_pjrt.inertia[slot], 0.0);
        let counts = &out_pjrt.counts[slot * spec.k..(slot + 1) * spec.k];
        assert!(counts.iter().all(|&c| c == 0.0));
    }
}

#[test]
fn pjrt_through_batcher_on_blobs() {
    let dir = require_artifacts!();
    let backend = PjrtBackend::load(&dir).unwrap();
    let data = make_blobs(&BlobSpec {
        num_points: 400,
        num_clusters: 5,
        dims: 2,
        std: 0.05,
        extent: 1.0,
        seed: 3,
    })
    .unwrap();
    // scale to [0,1] like the pipeline does
    use parsample::data::scaling::{MinMaxScaler, Scaler};
    let scaled = MinMaxScaler::new().fit_transform(&data).unwrap();
    let groups: Vec<Vec<usize>> = (0..4)
        .map(|g| (g * 100..(g + 1) * 100).collect())
        .collect();
    let batcher = Batcher::new(backend.manifest());
    let dispatches = batcher.plan(&scaled, &groups, 5.0).unwrap();
    assert!(!dispatches.is_empty());
    let mut total_counts = 0.0f32;
    for d in &dispatches {
        let out = backend.run_in_bucket(&d.bucket, &d.batch).unwrap();
        let native = NativeBackend::serial().run_batch(&d.batch).unwrap();
        assert_outputs_match(&out, &native, &d.batch, 1e-3);
        for r in Batcher::unpack(d, &out, 2) {
            total_counts += r.counts.iter().sum::<f32>();
            assert_eq!(r.centers.len(), r.counts.len() * 2);
            assert_eq!(r.counts.len(), local_k(100, 5.0));
        }
    }
    assert_eq!(total_counts, 400.0, "every real point accounted once");
}

#[test]
fn run_batch_routes_by_shape() {
    let dir = require_artifacts!();
    let backend = PjrtBackend::load(&dir).unwrap();
    let spec = backend.manifest().by_name("local_s").unwrap().clone();
    let batch = padded_batch(spec.b, spec.n, spec.d, spec.k, spec.iters, 20, 3, 4, 5);
    let out = backend.run_batch(&batch).unwrap();
    assert_eq!(out.inertia.len(), spec.b);
    // wrong iteration count is rejected
    let mut bad = batch.clone();
    bad.iters += 1;
    assert!(backend.run_batch(&bad).is_err());
}

#[test]
fn full_pipeline_pjrt_backend_end_to_end() {
    let dir = require_artifacts!();
    use parsample::pipeline::{PipelineConfig, SubclusterPipeline};
    use parsample::runtime::BackendKind;
    let data = make_blobs(&BlobSpec {
        num_points: 1200,
        num_clusters: 4,
        dims: 2,
        std: 0.05,
        extent: 10.0,
        seed: 9,
    })
    .unwrap();
    let cfg = PipelineConfig::builder()
        .final_k(4)
        .num_groups(5)
        .compression(5.0)
        .backend(BackendKind::Pjrt)
        .artifacts_dir(&dir)
        .build()
        .unwrap();
    let r = SubclusterPipeline::new(cfg).run(&data).unwrap();
    assert_eq!(r.labels.len(), 1200);
    assert_eq!(r.counts.iter().sum::<u32>(), 1200);
    // compare quality against the native path with identical settings
    let cfg_native = PipelineConfig::builder()
        .final_k(4)
        .num_groups(5)
        .compression(5.0)
        .backend(BackendKind::Native)
        .build()
        .unwrap();
    let rn = SubclusterPipeline::new(cfg_native).run(&data).unwrap();
    let ratio = r.inertia / rn.inertia.max(1e-9);
    assert!(
        (0.5..2.0).contains(&ratio),
        "pjrt {} vs native {} inertia",
        r.inertia,
        rn.inertia
    );
}
