//! Seeding bit-parity suite — the acceptance contract of the
//! k-means‖ initializer.
//!
//! Every `InitMethod` must produce **bit-identical** centers across
//! worker counts, tile kernels, and resident-vs-streamed access at any
//! chunk size (including chunk = 1 row and chunks that do not divide
//! M).  The suite also pins the k-means‖ oversampling bounds, the
//! degenerate edges (all-duplicate data, k = M), and the classic
//! k-means++ duplicate-mass fallback.

use parsample::cluster::init_parallel::{oversample, sampling_rounds, OVERSAMPLE};
use parsample::cluster::{
    initial_centers, initial_centers_source, initial_centers_with, BoundsMode, EngineOpts,
    InitMethod, KernelMode, MiniBatchKMeans,
};
use parsample::data::source::{ChunkedOnly, SliceSource};
use parsample::data::synthetic::{make_blobs, BlobSpec};
use parsample::data::Dataset;

fn blobs(m: usize, clusters: usize, dims: usize, seed: u64) -> Dataset {
    make_blobs(&BlobSpec {
        num_points: m,
        num_clusters: clusters,
        dims,
        std: 0.2,
        extent: 10.0,
        seed,
    })
    .unwrap()
}

fn opts(workers: usize, kernel: KernelMode) -> EngineOpts {
    EngineOpts { workers, bounds: BoundsMode::Off, kernel }
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Acceptance: k-means‖ centers are bit-identical at every worker
/// count × tile kernel.  The baseline is the serial scalar run.
#[test]
fn parallel_bit_identical_across_workers_and_kernels() {
    let data = blobs(1500, 8, 3, 1);
    let k = 16;
    let seed = 42;
    let baseline = initial_centers_with(
        data.as_slice(),
        data.dims(),
        k,
        InitMethod::KMeansParallel,
        seed,
        opts(1, KernelMode::Scalar),
    )
    .unwrap();
    assert_eq!(baseline.len(), k * data.dims());
    for workers in [1usize, 2, 8] {
        for kernel in [KernelMode::Scalar, KernelMode::Wide] {
            let got = initial_centers_with(
                data.as_slice(),
                data.dims(),
                k,
                InitMethod::KMeansParallel,
                seed,
                opts(workers, kernel),
            )
            .unwrap();
            assert_eq!(
                bits(&got),
                bits(&baseline),
                "workers={workers} kernel={kernel:?}"
            );
        }
    }
}

/// Acceptance: every method seeds bit-identically from a `DataSource`
/// at chunk sizes 1, a non-divisor of M, and larger than M —
/// `ChunkedOnly` defeats the resident fast path, so the streamed slab
/// walk is genuinely exercised.  The baseline is the resident slice.
#[test]
fn source_seeding_matches_resident_at_every_chunk_size() {
    let data = blobs(900, 6, 2, 2);
    let k = 12;
    let seed = 7;
    for method in [
        InitMethod::FirstK,
        InitMethod::Random,
        InitMethod::KMeansPlusPlus,
        InitMethod::KMeansParallel,
    ] {
        let resident = initial_centers_with(
            data.as_slice(),
            data.dims(),
            k,
            method,
            seed,
            opts(2, KernelMode::Wide),
        )
        .unwrap();
        for chunk in [1usize, 37, 4096] {
            let mut src = ChunkedOnly(
                SliceSource::new(data.as_slice(), data.dims())
                    .unwrap()
                    .with_chunk_rows(chunk),
            );
            let streamed =
                initial_centers_source(&mut src, k, method, seed, opts(2, KernelMode::Wide))
                    .unwrap();
            assert_eq!(
                bits(&streamed),
                bits(&resident),
                "{method:?} chunk={chunk}"
            );
        }
    }
}

/// Determinism replay: the same seed reproduces the same centers
/// bit for bit; a different seed moves them.
#[test]
fn parallel_deterministic_per_seed() {
    let data = blobs(800, 5, 3, 3);
    let run = |seed| {
        initial_centers(
            data.as_slice(),
            data.dims(),
            10,
            InitMethod::KMeansParallel,
            seed,
        )
        .unwrap()
    };
    assert_eq!(bits(&run(11)), bits(&run(11)));
    assert_ne!(bits(&run(11)), bits(&run(12)));
}

/// The oversampling contract: candidates are distinct input rows, at
/// least k of them, at most rounds·ℓ·k + 1 (the +1 is the seed
/// center), their rows match the input bytes, and the re-cluster
/// weights partition all M points.
#[test]
fn oversample_respects_bounds_and_weights_partition_input() {
    let data = blobs(2000, 10, 3, 4);
    let (m, dims, k) = (2000usize, data.dims(), 12usize);
    let mut src = SliceSource::of(&data);
    let cand = oversample(&mut src, k, 9, opts(4, KernelMode::Scalar)).unwrap();
    assert!(cand.idx.len() >= k, "{} candidates < k={k}", cand.idx.len());
    let cap = sampling_rounds(m) * OVERSAMPLE * k + 1;
    assert!(cand.idx.len() <= cap, "{} candidates > cap={cap}", cand.idx.len());
    assert_eq!(cand.rows.len(), cand.idx.len() * dims);
    assert_eq!(cand.weights.len(), cand.idx.len());
    let mut sorted = cand.idx.clone();
    sorted.sort_unstable();
    sorted.dedup();
    assert_eq!(sorted.len(), cand.idx.len(), "duplicate candidate index");
    for (slot, &gi) in cand.idx.iter().enumerate() {
        assert!(gi < m);
        assert_eq!(
            bits(&cand.rows[slot * dims..(slot + 1) * dims]),
            bits(&data.as_slice()[gi * dims..(gi + 1) * dims]),
            "candidate {slot} row mismatch"
        );
    }
    let total: u64 = cand.weights.iter().map(|&w| w as u64).sum();
    assert_eq!(total, m as u64, "weights must partition all input rows");
}

/// Degenerate edge: every input row identical.  The self-distance
/// cancellation zeroes the sampling mass after the first pick, so the
/// run must still terminate and return k copies of the point.
#[test]
fn parallel_handles_all_duplicate_rows() {
    let mut points = Vec::new();
    for _ in 0..40 {
        points.extend_from_slice(&[4.0f32, -1.5]);
    }
    let centers = initial_centers(&points, 2, 3, InitMethod::KMeansParallel, 0).unwrap();
    assert_eq!(centers.len(), 6);
    for c in centers.chunks(2) {
        assert_eq!(bits(c), bits(&[4.0, -1.5]));
    }
}

/// Degenerate edge: k = M.  Every input row must come back exactly
/// once — the centers are a permutation of the input.
#[test]
fn parallel_k_equals_m_returns_permutation_of_input() {
    let data = blobs(20, 4, 2, 5);
    let dims = data.dims();
    let centers =
        initial_centers(data.as_slice(), dims, 20, InitMethod::KMeansParallel, 3).unwrap();
    let mut got: Vec<Vec<u32>> = centers.chunks(dims).map(bits).collect();
    let mut want: Vec<Vec<u32>> = data.as_slice().chunks(dims).map(bits).collect();
    got.sort();
    want.sort();
    assert_eq!(got, want);
}

/// Regression: classic k-means++ on data whose distinct-point count is
/// below k.  The duplicate-mass fallback must fill the remaining
/// centers from untaken rows (covering every coordinate class) instead
/// of scanning O(k²·M) or erroring out.
#[test]
fn plusplus_duplicate_mass_fallback_covers_all_classes() {
    // 3 coordinate classes × 3 copies each; k = 7 > 3 distinct points.
    let classes = [[0.0f32, 0.0], [5.0, 5.0], [-5.0, 5.0]];
    let mut points = Vec::new();
    for class in &classes {
        for _ in 0..3 {
            points.extend_from_slice(class);
        }
    }
    let centers = initial_centers(&points, 2, 7, InitMethod::KMeansPlusPlus, 1).unwrap();
    assert_eq!(centers.len(), 14);
    for class in &classes {
        assert!(
            centers.chunks(2).any(|c| bits(c) == bits(class)),
            "class {class:?} missing from fallback-filled centers"
        );
    }
}

/// `Auto` at small k·M is the classic k-means++ bit for bit — the
/// default seeding of every pre-existing fixture is unchanged.
#[test]
fn auto_matches_plusplus_below_crossover() {
    let data = blobs(300, 3, 2, 6);
    let auto = initial_centers(data.as_slice(), data.dims(), 3, InitMethod::Auto, 9).unwrap();
    let pp = initial_centers(
        data.as_slice(),
        data.dims(),
        3,
        InitMethod::KMeansPlusPlus,
        9,
    )
    .unwrap();
    assert_eq!(bits(&auto), bits(&pp));
}

/// Mini-batch `fit_stream` seeded by k-means‖ is chunk-size
/// independent: the whole-stream seeding rounds and the batch rounds
/// after them see the same rows no matter how the source chops them.
#[test]
fn minibatch_parallel_seeding_is_chunk_size_independent() {
    let data = blobs(1200, 6, 2, 8);
    let mb = MiniBatchKMeans {
        k: 6,
        init: InitMethod::KMeansParallel,
        seed: 5,
        batch_size: 256,
        iters: 20,
        workers: 2,
        ..MiniBatchKMeans::default()
    };
    let baseline = {
        let mut src = SliceSource::of(&data);
        mb.fit_stream(&mut src).unwrap()
    };
    assert_eq!(baseline.rows, 1200);
    for chunk in [1usize, 193, 4096] {
        let mut src = ChunkedOnly(SliceSource::of(&data).with_chunk_rows(chunk));
        let got = mb.fit_stream(&mut src).unwrap();
        let ctx = format!("chunk={chunk}");
        assert_eq!(bits(&got.centers), bits(&baseline.centers), "{ctx}");
        assert_eq!(got.counts, baseline.counts, "{ctx}");
        assert_eq!(got.inertia.to_bits(), baseline.inertia.to_bits(), "{ctx}");
        assert_eq!(got.rows, baseline.rows, "{ctx}");
        assert_eq!(got.iterations, baseline.iterations, "{ctx}");
    }
}

/// Acceptance of the `init_oversample`/`init_rounds` knobs: the
/// explicit defaults are bit-identical to the knobless entry points
/// (`InitParams::default()` *is* the long-standing hard-wired
/// behavior), and out-of-range knobs are rejected up front.
#[test]
fn default_init_params_are_bit_identical_to_knobless_entry_points() {
    use parsample::cluster::init_parallel::oversample_params;
    use parsample::cluster::{
        initial_centers_source_params, initial_centers_with_params, InitParams,
    };

    assert_eq!(InitParams::default(), InitParams { oversample: OVERSAMPLE, rounds: None });

    let data = blobs(1200, 6, 3, 7);
    let (dims, k, seed) = (data.dims(), 10usize, 21u64);
    let knobless = initial_centers_with(
        data.as_slice(),
        dims,
        k,
        InitMethod::KMeansParallel,
        seed,
        opts(2, KernelMode::Scalar),
    )
    .unwrap();
    let explicit = initial_centers_with_params(
        data.as_slice(),
        dims,
        k,
        InitMethod::KMeansParallel,
        seed,
        opts(2, KernelMode::Scalar),
        InitParams::default(),
    )
    .unwrap();
    assert_eq!(bits(&explicit), bits(&knobless));

    let mut src = SliceSource::of(&data);
    let streamed = initial_centers_source_params(
        &mut src,
        k,
        InitMethod::KMeansParallel,
        seed,
        opts(2, KernelMode::Scalar),
        InitParams::default(),
    )
    .unwrap();
    assert_eq!(bits(&streamed), bits(&knobless));

    let mut src = SliceSource::of(&data);
    let base_cands = oversample(&mut src, k, seed, opts(1, KernelMode::Scalar)).unwrap();
    let mut src = SliceSource::of(&data);
    let param_cands =
        oversample_params(&mut src, k, seed, opts(1, KernelMode::Scalar), InitParams::default())
            .unwrap();
    assert_eq!(param_cands.idx, base_cands.idx);
    assert_eq!(bits(&param_cands.rows), bits(&base_cands.rows));
    assert_eq!(param_cands.weights, base_cands.weights);
}

/// The knobs actually steer the oversampling phase: an explicit round
/// count caps the candidate total at `rounds·ℓ·k + 1`, a larger ℓ
/// raises the expected draw count, and invalid values error.
#[test]
fn explicit_init_params_change_the_candidate_schedule() {
    use parsample::cluster::init_parallel::{oversample_params, MAX_INIT_ROUNDS};
    use parsample::cluster::InitParams;

    let data = blobs(2000, 10, 3, 4);
    let k = 12usize;
    let mut src = SliceSource::of(&data);
    let two_rounds = oversample_params(
        &mut src,
        k,
        9,
        opts(1, KernelMode::Scalar),
        InitParams { oversample: OVERSAMPLE, rounds: Some(2) },
    )
    .unwrap();
    assert!(two_rounds.idx.len() >= k);
    assert!(
        two_rounds.idx.len() <= 2 * OVERSAMPLE * k + 1,
        "{} candidates exceed the 2-round cap",
        two_rounds.idx.len()
    );

    let mut src = SliceSource::of(&data);
    let wide = oversample_params(
        &mut src,
        k,
        9,
        opts(1, KernelMode::Scalar),
        InitParams { oversample: 4, rounds: Some(2) },
    )
    .unwrap();
    assert!(
        wide.idx.len() > two_rounds.idx.len(),
        "l=4 drew {} candidates, no more than l=2's {}",
        wide.idx.len(),
        two_rounds.idx.len()
    );

    let mut src = SliceSource::of(&data);
    let bad = InitParams { oversample: 0, rounds: None };
    assert!(oversample_params(&mut src, k, 9, opts(1, KernelMode::Scalar), bad).is_err());
    let mut src = SliceSource::of(&data);
    let bad = InitParams { oversample: OVERSAMPLE, rounds: Some(0) };
    assert!(oversample_params(&mut src, k, 9, opts(1, KernelMode::Scalar), bad).is_err());
    let mut src = SliceSource::of(&data);
    let bad = InitParams { oversample: OVERSAMPLE, rounds: Some(MAX_INIT_ROUNDS + 1) };
    assert!(oversample_params(&mut src, k, 9, opts(1, KernelMode::Scalar), bad).is_err());
}
