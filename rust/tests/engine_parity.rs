//! Parity suite for the blocked multi-threaded assignment engine
//! (`cluster::engine`) against the scalar reference path.
//!
//! Contract under test:
//!   * labels and counts are bit-identical to the scalar per-point
//!     `nearest_sq_with_norms` sweep at every worker count, every
//!     blocking, dims {1,3,4,7,32}, and k up to m (ties and empty
//!     clusters included);
//!   * sums and inertia are bit-identical across worker counts
//!     {1,2,8} (block boundaries never depend on the worker count);
//!   * with a single reduction block (m <= point_block) — and on data
//!     whose partial sums are exactly representable — sums and inertia
//!     are bit-identical to the fully serial fold as well;
//!   * `lloyd_from_parallel` therefore reproduces the serial scalar
//!     Lloyd loop bit-for-bit (centers, labels, counts);
//!   * the Hamerly-bounded Lloyd loop (`BoundsMode::Hamerly`) is
//!     bit-identical to the unpruned loop (`BoundsMode::Off`) — every
//!     field, every worker count, every blocking, tol-early-stop or
//!     fixed iterations, ties and empty clusters included — because
//!     bounds only ever skip provably-unchanged argmins.

use parsample::cluster::engine::{serial_reference, BoundsMode, Engine, LloydLoopResult};
use parsample::cluster::init::{initial_centers, InitMethod};
use parsample::cluster::kmeans::{lloyd_from, lloyd_from_parallel, lloyd_from_with};
use parsample::data::synthetic::{make_blobs, BlobSpec};
use parsample::kernel::KernelMode;
use parsample::util::rng::Pcg32;

const DIMS: [usize; 5] = [1, 3, 4, 7, 32];
const WORKERS: [usize; 3] = [1, 2, 8];

fn cloud(m: usize, dims: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::seeded(seed);
    (0..m * dims).map(|_| rng.uniform(-8.0, 8.0)).collect()
}

#[test]
fn fused_pass_matches_scalar_reference() {
    // m < default point_block: single reduction block, so every field
    // — including f32 sums and f64 inertia — accumulates in exactly
    // the scalar order and must match bit-for-bit.
    for &dims in &DIMS {
        let m = 311;
        let pts = cloud(m, dims, 100 + dims as u64);
        for k in [1usize, 2, 13, m] {
            let centers = pts[..k * dims].to_vec();
            let reference = serial_reference(&pts, dims, &centers);
            for &w in &WORKERS {
                let pass = Engine::new(w).assign_accumulate(&pts, dims, &centers);
                assert_eq!(pass.labels, reference.labels, "dims={dims} k={k} w={w}");
                assert_eq!(pass.counts, reference.counts, "dims={dims} k={k} w={w}");
                assert_eq!(pass.sums, reference.sums, "dims={dims} k={k} w={w}");
                assert_eq!(
                    pass.inertia.to_bits(),
                    reference.inertia.to_bits(),
                    "dims={dims} k={k} w={w}"
                );
            }
        }
    }
}

#[test]
fn k_equals_m_has_exactly_zero_inertia() {
    for &dims in &DIMS {
        // strictly increasing coordinates: every row is distinct, so
        // each point's unique argmin is its own center
        let pts: Vec<f32> = (0..40 * dims).map(|i| i as f32 * 0.25 - 13.0).collect();
        for &w in &WORKERS {
            let pass = Engine::new(w).assign_accumulate(&pts, dims, &pts);
            assert_eq!(pass.inertia, 0.0, "dims={dims} w={w}");
            assert_eq!(pass.counts, vec![1u32; 40], "dims={dims} w={w}");
        }
    }
}

#[test]
fn blocked_labels_still_match_scalar_reference() {
    // Force many blocks and tiny center tiles: labels/counts must stay
    // bit-identical to the scalar sweep regardless of blocking.
    for &dims in &[3usize, 32] {
        let m = 2500;
        let pts = cloud(m, dims, 200 + dims as u64);
        let k = 37;
        let centers = pts[..k * dims].to_vec();
        let reference = serial_reference(&pts, dims, &centers);
        for &w in &WORKERS {
            let e = Engine::with_blocking(w, 128, 5);
            let pass = e.assign_accumulate(&pts, dims, &centers);
            assert_eq!(pass.labels, reference.labels, "dims={dims} w={w}");
            assert_eq!(pass.counts, reference.counts, "dims={dims} w={w}");
            // multi-block f32 partial merges may differ from the serial
            // fold in the last ulp; they must still be very tight
            for (i, (a, b)) in pass.sums.iter().zip(&reference.sums).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-3 * (1.0 + b.abs()),
                    "dims={dims} w={w} sums[{i}]: {a} vs {b}"
                );
            }
            let rel =
                (pass.inertia - reference.inertia).abs() / (1.0 + reference.inertia.abs());
            assert!(rel < 1e-9, "dims={dims} w={w}: {} vs {}", pass.inertia, reference.inertia);
        }
    }
}

#[test]
fn blocked_outputs_bit_identical_across_worker_counts() {
    let dims = 7;
    let m = 3000;
    let pts = cloud(m, dims, 31);
    let centers = pts[..29 * dims].to_vec();
    let e1 = Engine::with_blocking(1, 64, 3);
    let base = e1.assign_accumulate(&pts, dims, &centers);
    for &w in &[2usize, 8] {
        let pass = Engine::with_blocking(w, 64, 3).assign_accumulate(&pts, dims, &centers);
        assert_eq!(pass.labels, base.labels, "w={w}");
        assert_eq!(pass.counts, base.counts, "w={w}");
        assert_eq!(pass.sums, base.sums, "w={w}");
        assert_eq!(pass.inertia.to_bits(), base.inertia.to_bits(), "w={w}");
    }
}

#[test]
fn integer_data_blocked_sums_bitwise_equal_serial() {
    // Small-integer coordinates keep every partial sum exactly
    // representable in f32, so even the multi-block merge must equal
    // the serial fold bit-for-bit.
    let dims = 3;
    let m = 1000;
    let mut rng = Pcg32::seeded(9);
    let pts: Vec<f32> = (0..m * dims).map(|_| rng.below(32) as f32).collect();
    let centers: Vec<f32> = (0..6 * dims).map(|_| rng.below(32) as f32).collect();
    let reference = serial_reference(&pts, dims, &centers);
    for &w in &WORKERS {
        let pass = Engine::with_blocking(w, 100, 2).assign_accumulate(&pts, dims, &centers);
        assert_eq!(pass.labels, reference.labels, "w={w}");
        assert_eq!(pass.counts, reference.counts, "w={w}");
        assert_eq!(pass.sums, reference.sums, "w={w}");
        assert_eq!(pass.inertia.to_bits(), reference.inertia.to_bits(), "w={w}");
    }
}

#[test]
fn tie_and_empty_cluster_cases() {
    // duplicate centers across a tile boundary: lowest index wins
    let dims = 4;
    let pts = cloud(150, dims, 77);
    let mut centers = Vec::new();
    for _ in 0..12 {
        centers.extend_from_slice(&[0.5f32, -1.0, 2.0, 0.25]);
    }
    // plus one far-away center nothing selects
    centers.extend_from_slice(&[1e6, 1e6, 1e6, 1e6]);
    let reference = serial_reference(&pts, dims, &centers);
    for &w in &WORKERS {
        let pass = Engine::with_blocking(w, 32, 5).assign_accumulate(&pts, dims, &centers);
        assert_eq!(pass.labels, reference.labels, "w={w}");
        assert!(pass.labels.iter().all(|&l| l == 0), "ties must break to center 0");
        assert_eq!(*pass.counts.last().unwrap(), 0, "far center must stay empty");
        assert_eq!(&pass.sums[12 * dims..], &[0.0f32; 4], "empty center sums stay zero");
    }
}

#[test]
fn assign_only_and_inertia_agree_with_fused_pass() {
    let dims = 5;
    let pts = cloud(640, dims, 55);
    let centers = pts[..17 * dims].to_vec();
    for &w in &WORKERS {
        let e = Engine::with_blocking(w, 96, 4);
        let pass = e.assign_accumulate(&pts, dims, &centers);
        assert_eq!(e.assign_only(&pts, dims, &centers), pass.labels, "w={w}");
        assert_eq!(
            e.inertia(&pts, dims, &centers).to_bits(),
            pass.inertia.to_bits(),
            "w={w}"
        );
        let acc = e.accumulate_only(&pts, dims, &centers);
        assert_eq!(acc.counts, pass.counts, "w={w}");
        assert_eq!(acc.sums, pass.sums, "w={w}");
    }
}

fn assert_loops_eq(a: &LloydLoopResult, b: &LloydLoopResult, ctx: &str) {
    assert_eq!(a.labels, b.labels, "{ctx}");
    assert_eq!(a.counts, b.counts, "{ctx}");
    assert_eq!(a.centers, b.centers, "{ctx}");
    assert_eq!(a.inertia.to_bits(), b.inertia.to_bits(), "{ctx}");
    assert_eq!(a.iterations, b.iterations, "{ctx}");
}

#[test]
fn prop_bounded_lloyd_bit_identical_to_unbounded() {
    // The tentpole contract: Hamerly pruning must not change a single
    // bit of any output — across dims {1, 2, 7, 32}, k up to m,
    // workers {1, 8}, fixed-iteration and tol-early-stop runs alike.
    for &dims in &[1usize, 2, 7, 32] {
        let m = 240;
        let pts = cloud(m, dims, 900 + dims as u64);
        for &k in &[1usize, 2, 19, m] {
            let init = pts[..k * dims].to_vec();
            for &(iters, tol) in &[(12usize, 0.0f32), (60, 1e-5)] {
                for &w in &[1usize, 8] {
                    let e = Engine::with_blocking(w, 64, 4);
                    let off = e.lloyd_loop(&pts, dims, init.clone(), iters, tol, BoundsMode::Off);
                    let ham =
                        e.lloyd_loop(&pts, dims, init.clone(), iters, tol, BoundsMode::Hamerly);
                    assert_loops_eq(
                        &ham,
                        &off,
                        &format!("dims={dims} k={k} iters={iters} tol={tol} w={w}"),
                    );
                    assert_eq!(
                        ham.stats.point_iters(),
                        m as u64 * (ham.iterations as u64 + 1),
                        "dims={dims} k={k} iters={iters} tol={tol} w={w}"
                    );
                }
            }
        }
    }
}

#[test]
fn bounded_lloyd_bit_identical_across_worker_counts() {
    let dims = 5;
    let m = 2600;
    let pts = cloud(m, dims, 606);
    let init = pts[..23 * dims].to_vec();
    let base = Engine::with_blocking(1, 128, 4)
        .lloyd_loop(&pts, dims, init.clone(), 15, 0.0, BoundsMode::Hamerly);
    for &w in &[2usize, 8] {
        let run = Engine::with_blocking(w, 128, 4)
            .lloyd_loop(&pts, dims, init.clone(), 15, 0.0, BoundsMode::Hamerly);
        assert_loops_eq(&run, &base, &format!("w={w}"));
        // skip decisions are state-driven, so even the per-iteration
        // counters must be identical across worker counts
        assert_eq!(run.stats, base.stats, "w={w}");
    }
}

#[test]
fn bounded_lloyd_via_kmeans_entrypoint_matches_off() {
    for &dims in &[2usize, 7] {
        let m = 700;
        let pts = cloud(m, dims, 3000 + dims as u64);
        let init = pts[..13 * dims].to_vec();
        for &w in &[1usize, 8] {
            let kern = KernelMode::session_default();
            let off =
                lloyd_from_with(&pts, dims, init.clone(), 20, 1e-6, w, BoundsMode::Off, kern)
                    .unwrap();
            let ham =
                lloyd_from_with(&pts, dims, init.clone(), 20, 1e-6, w, BoundsMode::Hamerly, kern)
                    .unwrap();
            assert_eq!(ham.labels, off.labels, "dims={dims} w={w}");
            assert_eq!(ham.counts, off.counts, "dims={dims} w={w}");
            assert_eq!(ham.centers, off.centers, "dims={dims} w={w}");
            assert_eq!(ham.inertia.to_bits(), off.inertia.to_bits(), "dims={dims} w={w}");
            assert_eq!(ham.iterations, off.iterations, "dims={dims} w={w}");
        }
    }
}

#[test]
fn bounded_empty_cluster_keeps_center_zero_shift() {
    // Two tight pairs plus one faraway center that goes empty: its
    // shift is zero every iteration (the empty-cluster-keeps-center
    // rule) and both modes must leave it exactly in place.
    let pts = vec![0.0f32, 0.0, 0.1, 0.0, 10.0, 10.0, 10.1, 10.0];
    let init = vec![0.0f32, 0.0, 10.0, 10.0, 500.0, 500.0];
    for &w in &[1usize, 2, 8] {
        let e = Engine::new(w);
        let off = e.lloyd_loop(&pts, 2, init.clone(), 6, 0.0, BoundsMode::Off);
        let ham = e.lloyd_loop(&pts, 2, init.clone(), 6, 0.0, BoundsMode::Hamerly);
        assert_loops_eq(&ham, &off, &format!("w={w}"));
        assert_eq!(&ham.centers[4..6], &[500.0, 500.0], "w={w}");
        assert_eq!(ham.counts[2], 0, "w={w}");
    }
}

#[test]
fn bounded_duplicate_centers_tie_to_lowest_index() {
    // Duplicate initial centers straddling tile boundaries: ties must
    // keep breaking to the lowest index under pruning too.
    let dims = 3;
    let pts = cloud(500, dims, 41);
    let mut init = Vec::new();
    for _ in 0..9 {
        init.extend_from_slice(&pts[..dims]);
    }
    init.extend_from_slice(&pts[dims..4 * dims]);
    for &w in &[1usize, 8] {
        let e = Engine::with_blocking(w, 64, 4);
        let off = e.lloyd_loop(&pts, dims, init.clone(), 8, 0.0, BoundsMode::Off);
        let ham = e.lloyd_loop(&pts, dims, init.clone(), 8, 0.0, BoundsMode::Hamerly);
        assert_loops_eq(&ham, &off, &format!("w={w}"));
    }
}

#[test]
fn bounds_skip_most_point_iterations_once_converged() {
    // Well-separated blobs: once centers stop moving, nearly every
    // point-iteration must be pruned.  The bench reports the real
    // skip rate; this test only guards against the counters rotting.
    let ds = make_blobs(&BlobSpec {
        num_points: 4000,
        num_clusters: 16,
        dims: 4,
        std: 0.05,
        extent: 10.0,
        seed: 33,
    })
    .unwrap();
    let init =
        initial_centers(ds.as_slice(), 4, 16, InitMethod::KMeansPlusPlus, 7).unwrap();
    let run = Engine::new(2).lloyd_loop(ds.as_slice(), 4, init, 20, 0.0, BoundsMode::Hamerly);
    assert_eq!(run.iterations, 20);
    assert_eq!(run.stats.point_iters(), 4000 * 21);
    assert_eq!(run.stats.per_iter[0].skipped, 0, "cold sweep cannot skip");
    assert!(
        run.stats.skip_rate_from(5) > 0.5,
        "expected >50% skips after iteration 5, got {}",
        run.stats.skip_rate_from(5)
    );
}

#[test]
fn lloyd_parallel_bit_identical_to_serial_lloyd() {
    // m < point_block: the whole Lloyd loop (assign, accumulate,
    // update, final pass) must be bit-for-bit reproducible at every
    // worker count.
    for &dims in &[2usize, 7] {
        let m = 900;
        let pts = cloud(m, dims, 400 + dims as u64);
        let init = pts[..9 * dims].to_vec();
        let serial = lloyd_from(&pts, dims, init.clone(), 12, 0.0).unwrap();
        for &w in &[2usize, 8] {
            let par = lloyd_from_parallel(&pts, dims, init.clone(), 12, 0.0, w).unwrap();
            assert_eq!(par.centers, serial.centers, "dims={dims} w={w}");
            assert_eq!(par.labels, serial.labels, "dims={dims} w={w}");
            assert_eq!(par.counts, serial.counts, "dims={dims} w={w}");
            assert_eq!(par.inertia.to_bits(), serial.inertia.to_bits(), "dims={dims} w={w}");
            assert_eq!(par.iterations, serial.iterations, "dims={dims} w={w}");
        }
    }
}
