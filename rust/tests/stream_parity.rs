//! Streaming-vs-resident bit-parity suite — the acceptance contract of
//! the `DataSource` ingestion redesign.
//!
//! For every `DataSource` kind backed by identical bytes,
//! `fit_source`/`predict_source` results (centers, labels, inertia,
//! iteration counts, scaler params) must be **bit-identical** to the
//! resident `fit`/`predict` at every tested chunk size (including
//! chunk = 1 row and chunks that do not divide M) and at every
//! `EngineOpts` setting (worker count × bounds × kernel).

use parsample::cluster::{BoundsMode, EngineOpts, KernelMode};
use parsample::data::loader::{save_binary, save_csv};
use parsample::data::source::{
    BinarySource, BlobSource, ChunkedOnly, CsvSource, DataSource, DatasetSource, SliceSource,
};
use parsample::data::synthetic::{make_blobs, BlobSpec};
use parsample::data::Dataset;
use parsample::model::{FittedModel, ModelSpec};
use parsample::partition::Scheme;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "parsample_sparity_{tag}_{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn blobs(m: usize, k: usize, dims: usize, seed: u64) -> Dataset {
    make_blobs(&BlobSpec {
        num_points: m,
        num_clusters: k,
        dims,
        std: 0.2,
        extent: 10.0,
        seed,
    })
    .unwrap()
}

/// Every source kind backed by the same bytes as `data` (written once
/// into `dir`), at the given chunk size.
fn all_sources(
    data: &Dataset,
    dir: &std::path::Path,
    chunk: usize,
) -> Vec<(String, Box<dyn DataSource>)> {
    let plain = Dataset::new(data.as_slice().to_vec(), data.dims()).unwrap();
    let csv = dir.join(format!("d{}.csv", data.dims()));
    let bin = dir.join(format!("d{}.bin", data.dims()));
    save_csv(&plain, &csv).unwrap();
    save_binary(&plain, &bin).unwrap();
    let mem = DatasetSource::new(plain.clone()).with_chunk_rows(chunk);
    vec![
        ("dataset".into(), Box::new(mem) as Box<dyn DataSource>),
        (
            "chunked-mem".into(),
            Box::new(ChunkedOnly(DatasetSource::new(plain).with_chunk_rows(chunk))),
        ),
        (
            "csv".into(),
            Box::new(CsvSource::open(&csv, None).unwrap().with_chunk_rows(chunk)),
        ),
        (
            "bin".into(),
            Box::new(BinarySource::open(&bin).unwrap().with_chunk_rows(chunk)),
        ),
    ]
}

/// Bit-level artifact equality.
fn assert_models_eq(a: &FittedModel, b: &FittedModel, ctx: &str) {
    assert_eq!(a.meta().algorithm, b.meta().algorithm, "{ctx}");
    assert_eq!(a.meta().k, b.meta().k, "{ctx}");
    assert_eq!(a.meta().dims, b.meta().dims, "{ctx}");
    assert_eq!(a.meta().trained_on, b.meta().trained_on, "{ctx}");
    assert_eq!(a.meta().iterations, b.meta().iterations, "{ctx}");
    assert_eq!(
        a.meta().inertia.to_bits(),
        b.meta().inertia.to_bits(),
        "{ctx}: inertia {} vs {}",
        a.meta().inertia,
        b.meta().inertia
    );
    assert_eq!(
        a.centers().iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        b.centers().iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        "{ctx}: centers"
    );
    match (a.scaler(), b.scaler()) {
        (None, None) => {}
        (Some(sa), Some(sb)) => {
            assert_eq!(sa.params().0, sb.params().0, "{ctx}: scaler mins");
            assert_eq!(sa.params().1, sb.params().1, "{ctx}: scaler ranges");
        }
        _ => panic!("{ctx}: scaler presence differs"),
    }
}

fn spec_for(algo: &str, k: usize) -> ModelSpec {
    let mut spec = ModelSpec::new(algo, k);
    spec.num_groups = Some(5);
    spec.compression = Some(4.0);
    spec
}

/// Acceptance: every algorithm's `fit_source` — streaming consumers
/// (minibatch, pipeline) and spill fallbacks (kmeans, bisecting) —
/// matches the resident `fit` bit for bit, for every source kind, at
/// chunk sizes 1, a non-divisor of M, and larger than M.
#[test]
fn fit_source_matches_fit_for_every_kind_and_chunk() {
    let dir = tmpdir("fit");
    let data = blobs(600, 4, 2, 1);
    for algo in ["kmeans", "minibatch", "bisecting", "pipeline"] {
        let spec = spec_for(algo, 4);
        let resident = spec.fit(&data).unwrap();
        for chunk in [1usize, 193, 4096] {
            for (kind, mut src) in all_sources(&data, &dir, chunk) {
                let streamed = spec.fit_source(&mut *src).unwrap();
                assert_models_eq(&streamed, &resident, &format!("{algo}/{kind}/chunk={chunk}"));
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Acceptance: the bit-parity holds at every engine setting — worker
/// count × bounds × kernel — for both true streaming consumers.
#[test]
fn fit_source_parity_across_engine_opts_grid() {
    let dir = tmpdir("grid");
    let data = blobs(500, 3, 3, 2);
    for algo in ["minibatch", "pipeline"] {
        for workers in [1usize, 4] {
            for bounds in [BoundsMode::Off, BoundsMode::Hamerly] {
                for kernel in [KernelMode::Scalar, KernelMode::Wide] {
                    let mut spec = spec_for(algo, 3);
                    spec.engine = EngineOpts { workers, bounds, kernel };
                    let resident = spec.fit(&data).unwrap();
                    for (kind, mut src) in all_sources(&data, &dir, 97) {
                        let streamed = spec.fit_source(&mut *src).unwrap();
                        assert_models_eq(
                            &streamed,
                            &resident,
                            &format!("{algo}/{kind}/w{workers}/{bounds:?}/{kernel:?}"),
                        );
                    }
                }
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Acceptance: pipeline `fit_source` parity per scheme — unequal and
/// random stream through the scatter, equal takes the documented
/// spill fallback; all three must equal the resident fit.
#[test]
fn pipeline_fit_source_parity_per_scheme() {
    let dir = tmpdir("scheme");
    let data = blobs(800, 4, 2, 3);
    for scheme in [Scheme::Unequal, Scheme::Random, Scheme::Equal] {
        let mut spec = spec_for("pipeline", 4);
        spec.scheme = Some(scheme);
        spec.seed = 7;
        let resident = spec.fit(&data).unwrap();
        for chunk in [31usize, 800] {
            for (kind, mut src) in all_sources(&data, &dir, chunk) {
                let streamed = spec.fit_source(&mut *src).unwrap();
                assert_models_eq(
                    &streamed,
                    &resident,
                    &format!("{scheme:?}/{kind}/chunk={chunk}"),
                );
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Acceptance: `predict_source` labels/counts/inertia are bit-equal to
/// the resident predict for every source kind, chunk size, and engine
/// setting.  M = 9000 crosses the engine's 4096-point reduction-block
/// boundary, so the f64 inertia fold is genuinely multi-block.
#[test]
fn predict_source_matches_predict_for_every_kind() {
    let dir = tmpdir("pred");
    let data = blobs(9000, 6, 2, 4);
    let model = spec_for("kmeans", 6).fit(&data).unwrap();
    let resident = model.predict_dataset(&data).unwrap();
    for chunk in [1usize, 997, 8192, 20000] {
        for (kind, mut src) in all_sources(&data, &dir, chunk) {
            let mut labels: Vec<u32> = Vec::new();
            let p = model
                .predict_source(&mut *src, |ls| {
                    labels.extend_from_slice(ls);
                    Ok(())
                })
                .unwrap();
            let ctx = format!("{kind}/chunk={chunk}");
            assert_eq!(p.rows, 9000, "{ctx}");
            assert_eq!(labels, resident.labels, "{ctx}");
            assert_eq!(p.counts, resident.counts, "{ctx}");
            assert_eq!(p.inertia.to_bits(), resident.inertia.to_bits(), "{ctx}");
        }
    }
    // engine-opts grid on one streamed kind
    for workers in [1usize, 4] {
        for kernel in [KernelMode::Scalar, KernelMode::Wide] {
            let opts = EngineOpts { workers, bounds: BoundsMode::Hamerly, kernel };
            let resident = model
                .predict_batch_with(data.as_slice(), opts)
                .unwrap();
            let csv = dir.join("d2.csv");
            let mut src = CsvSource::open(&csv, None).unwrap().with_chunk_rows(611);
            let mut labels: Vec<u32> = Vec::new();
            let p = model
                .predict_source_with(&mut src, opts, |ls| {
                    labels.extend_from_slice(ls);
                    Ok(())
                })
                .unwrap();
            let ctx = format!("csv/w{workers}/{kernel:?}");
            assert_eq!(labels, resident.labels, "{ctx}");
            assert_eq!(p.counts, resident.counts, "{ctx}");
            assert_eq!(p.inertia.to_bits(), resident.inertia.to_bits(), "{ctx}");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The synthetic generator as a source: fitting a stream of blobs is
/// bit-identical to fitting the resident `make_blobs` dataset — no
/// giant file (or buffer) needed for out-of-core runs.
#[test]
fn blob_source_fit_matches_resident_make_blobs() {
    let spec = BlobSpec {
        num_points: 1500,
        num_clusters: 5,
        dims: 2,
        std: 0.1,
        extent: 8.0,
        seed: 12,
    };
    let resident_data = make_blobs(&spec).unwrap();
    let mspec = spec_for("minibatch", 5);
    let resident = mspec.fit(&resident_data).unwrap();
    for chunk in [64usize, 1500] {
        let mut src = BlobSource::new(&spec).unwrap().with_chunk_rows(chunk);
        let streamed = mspec.fit_source(&mut src).unwrap();
        assert_models_eq(&streamed, &resident, &format!("blob/chunk={chunk}"));
    }
}

/// Mid-stream CSV corruption fails a streaming fit with the offending
/// line number — not a silent truncation.
#[test]
fn corrupt_csv_fails_fit_with_line_number() {
    let dir = tmpdir("corrupt");
    let path = dir.join("bad.csv");
    let mut text = String::new();
    for i in 0..50 {
        text.push_str(&format!("{}.5,{}\n", i, i * 2));
    }
    text.push_str("oops,not-a-number\n");
    text.push_str("9,9\n");
    std::fs::write(&path, &text).unwrap();
    let mut src = CsvSource::open(&path, None).unwrap().with_chunk_rows(7);
    let err = spec_for("minibatch", 3)
        .fit_source(&mut src)
        .unwrap_err()
        .to_string();
    assert!(err.contains("line 51"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

/// Sanity: a `SliceSource` fit (the zero-copy resident fast path) and
/// a fully chunked fit of the same bytes agree — the two routes
/// through `fit_source` are one algorithm.
#[test]
fn resident_fast_path_equals_chunked_path() {
    let data = blobs(400, 3, 2, 9);
    let spec = spec_for("minibatch", 3);
    let via_slice = {
        let mut src = SliceSource::of(&data);
        spec.fit_source(&mut src).unwrap()
    };
    let via_chunks = {
        let mut src = ChunkedOnly(DatasetSource::new(data.clone()).with_chunk_rows(11));
        spec.fit_source(&mut src).unwrap()
    };
    assert_models_eq(&via_chunks, &via_slice, "slice vs chunked");
}
