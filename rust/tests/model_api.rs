//! Integration: the fit/predict model API end to end.
//!
//! Pins the PR's acceptance contract: `FittedModel::predict` labels are
//! bit-identical to `Engine::assign_full` (the engine's fused
//! assign-accumulate pass) on the same centers for **every**
//! `EngineOpts` combination, and a save→load roundtrip changes nothing.

use parsample::cluster::{BoundsMode, Engine, EngineOpts, KernelMode};
use parsample::data::synthetic::{make_blobs, BlobSpec};
use parsample::data::Dataset;
use parsample::model::{ClusterModel, FittedModel, KMeans, ModelSpec};
use parsample::pipeline::{assign_full, PipelineConfig, SubclusterPipeline};

fn blobs(m: usize, k: usize, dims: usize, seed: u64) -> Dataset {
    make_blobs(&BlobSpec {
        num_points: m,
        num_clusters: k,
        dims,
        std: 0.05,
        extent: 10.0,
        seed,
    })
    .unwrap()
}

fn tmp_path(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("parsample_model_api_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Acceptance: predict labels bit-identical to the engine's fused pass
/// over the full bounds × kernel × workers grid.
#[test]
fn predict_matches_assign_full_for_every_engine_opts_combination() {
    let data = blobs(700, 5, 3, 11);
    let model = KMeans::new(5).fit(&data).unwrap();
    // serial scalar reference on the same centers
    let reference = Engine::serial().assign_accumulate(data.as_slice(), 3, model.centers());
    for bounds in [BoundsMode::Off, BoundsMode::Hamerly] {
        for kernel in [KernelMode::Scalar, KernelMode::Wide, KernelMode::Auto] {
            for workers in [1usize, 2, 8] {
                let opts = EngineOpts { workers, bounds, kernel };
                let p = model.predict_batch_with(data.as_slice(), opts).unwrap();
                let tag = format!("{bounds:?}/{kernel:?}/w{workers}");
                assert_eq!(p.labels, reference.labels, "{tag}");
                assert_eq!(p.counts, reference.counts, "{tag}");
                assert_eq!(p.inertia.to_bits(), reference.inertia.to_bits(), "{tag}");
                // and against assign_full itself (the public seam)
                let (labels, counts, inertia) =
                    assign_full(data.as_slice(), 3, model.centers(), workers, kernel);
                assert_eq!(p.labels, labels, "{tag}");
                assert_eq!(p.counts, counts, "{tag}");
                assert_eq!(p.inertia.to_bits(), inertia.to_bits(), "{tag}");
            }
        }
    }
}

/// Acceptance: save → load → predict roundtrip parity, including the
/// fitted scaler, for the pipeline model.
#[test]
fn save_load_predict_roundtrip_parity() {
    let data = blobs(900, 4, 2, 3);
    let cfg = PipelineConfig::builder()
        .final_k(4)
        .num_groups(4)
        .compression(4.0)
        .build()
        .unwrap();
    let model = SubclusterPipeline::new(cfg).fit(&data).unwrap();
    let before = model.predict_dataset(&data).unwrap();

    let path = tmp_path("pipeline.model.json");
    model.save(&path).unwrap();
    let loaded = FittedModel::load(&path).unwrap();

    assert_eq!(loaded.meta(), model.meta());
    let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(loaded.centers()), bits(model.centers()));
    let (lm, lr) = loaded.scaler().expect("pipeline stores its scaler").params();
    let (om, or) = model.scaler().unwrap().params();
    assert_eq!(bits(lm), bits(om));
    assert_eq!(bits(lr), bits(or));

    let after = loaded.predict_dataset(&data).unwrap();
    assert_eq!(before.labels, after.labels);
    assert_eq!(before.counts, after.counts);
    assert_eq!(before.inertia.to_bits(), after.inertia.to_bits());
    std::fs::remove_file(&path).ok();
}

/// The roundtrip also holds across engine-opts retuning on the loaded
/// side: a model saved with one knob set predicts identically under
/// another.
#[test]
fn loaded_model_retuned_engine_is_bit_identical() {
    let data = blobs(500, 3, 4, 7);
    let model = KMeans::new(3)
        .with_engine_opts(EngineOpts {
            workers: 2,
            bounds: BoundsMode::Hamerly,
            kernel: KernelMode::Wide,
        })
        .fit(&data)
        .unwrap();
    let path = tmp_path("kmeans.model.json");
    model.save(&path).unwrap();
    let loaded = FittedModel::load(&path).unwrap();
    // provenance survived
    assert_eq!(loaded.meta().engine.workers, 2);
    assert_eq!(loaded.meta().engine.kernel, KernelMode::Wide);
    let a = model.predict_dataset(&data).unwrap();
    let b = loaded
        .with_engine_opts(EngineOpts::serial().with_workers(8))
        .predict_dataset(&data)
        .unwrap();
    assert_eq!(a.labels, b.labels);
    assert_eq!(a.inertia.to_bits(), b.inertia.to_bits());
    std::fs::remove_file(&path).ok();
}

/// Every ModelSpec algorithm fits, saves, loads, and predicts.
#[test]
fn every_algorithm_roundtrips_through_disk() {
    let data = blobs(400, 3, 2, 9);
    for algo in ["kmeans", "minibatch", "bisecting", "pipeline"] {
        let mut spec = ModelSpec::new(algo, 3);
        spec.num_groups = Some(4);
        spec.compression = Some(4.0);
        let model = spec.fit(&data).unwrap_or_else(|e| panic!("{algo}: {e}"));
        let path = tmp_path(&format!("{algo}.model.json"));
        model.save(&path).unwrap();
        let loaded = FittedModel::load(&path).unwrap();
        let a = model.predict_dataset(&data).unwrap();
        let b = loaded.predict_dataset(&data).unwrap();
        assert_eq!(a.labels, b.labels, "{algo}");
        assert_eq!(a.counts.iter().sum::<u32>(), 400, "{algo}");
        std::fs::remove_file(&path).ok();
    }
}

/// predict() on a single point agrees with predict_batch row-wise.
#[test]
fn single_point_predict_matches_batch() {
    let data = blobs(300, 4, 3, 5);
    let model = KMeans::new(4).fit(&data).unwrap();
    let batch = model.predict_dataset(&data).unwrap();
    for i in (0..data.len()).step_by(29) {
        assert_eq!(model.predict(data.row(i)).unwrap(), batch.labels[i], "point {i}");
    }
}

/// Fitting through the trait records honest metadata.
#[test]
fn fit_metadata_reflects_the_run() {
    let data = blobs(250, 2, 2, 13);
    let model = KMeans::new(2).fit(&data).unwrap();
    let meta = model.meta();
    assert_eq!(meta.algorithm, "kmeans");
    assert_eq!((meta.k, meta.dims, meta.trained_on), (2, 2, 250));
    // fit inertia equals a fresh engine inertia sweep over the centers
    let engine_inertia = Engine::serial().inertia(data.as_slice(), 2, model.centers());
    assert!((meta.inertia - engine_inertia).abs() < 1e-6);
}
