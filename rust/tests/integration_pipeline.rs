//! Integration: the full pipeline against the paper's claims on
//! realistic (scaled-down) workloads, plus failure injection.

use parsample::data::builtin;
use parsample::data::synthetic::{make_blobs, paper_scaling_dataset, BlobSpec};
use parsample::eval;
use parsample::partition::Scheme;
use parsample::pipeline::{
    traditional_kmeans, PipelineConfig, SubclusterPipeline,
};

/// T1 regime: subclustered accuracy within a few points of (or above)
/// the standard-kmeans baseline on both labelled datasets.
#[test]
fn table1_regime_holds() {
    for (name, data, min_correct) in [
        ("iris", builtin::iris(), 130u64),
        ("seeds", builtin::seeds_sim(0), 185),
    ] {
        let truth = data.labels().unwrap().to_vec();
        let base = traditional_kmeans(&data, 3, 100, 0).unwrap();
        let base_correct = eval::correct_count(&base.labels, &truth).unwrap();
        assert!(
            base_correct >= min_correct,
            "{name}: baseline {base_correct} below the paper regime"
        );
        for scheme in [Scheme::Equal, Scheme::Unequal] {
            let cfg = PipelineConfig::builder()
                .scheme(scheme)
                .num_groups(6)
                .compression(6.0)
                .final_k(3)
                .weighted_global(true)
                .build()
                .unwrap();
            let r = SubclusterPipeline::new(cfg).run(&data).unwrap();
            let correct = eval::correct_count(&r.labels, &truth).unwrap();
            // paper: subclustered >= standard; allow a small margin
            assert!(
                correct + 4 >= base_correct,
                "{name} {scheme:?}: {correct} well below baseline {base_correct}"
            );
        }
    }
}

/// T2 regime (scaled down): the pipeline's advantage grows with M
/// because K = M/500 grows while the pipeline's cost is ~linear.
#[test]
fn table2_speedup_grows_with_size() {
    let time = |f: &mut dyn FnMut()| {
        let t0 = std::time::Instant::now();
        f();
        t0.elapsed().as_secs_f64()
    };
    let mut ratios = Vec::new();
    for m in [20_000usize, 80_000] {
        let k = m / 500;
        let data = paper_scaling_dataset(m, 7).unwrap();
        let trad = time(&mut || {
            parsample::pipeline::traditional_kmeans_restarts(&data, k, 25, 0, 1).unwrap();
        });
        let cfg = PipelineConfig::builder()
            .compression(5.0)
            .final_k(k)
            .weighted_global(true)
            .build()
            .unwrap();
        let pipeline = SubclusterPipeline::new(cfg);
        let par = time(&mut || {
            pipeline.run(&data).unwrap();
        });
        ratios.push(trad / par);
    }
    assert!(
        ratios[1] > ratios[0],
        "speedup must grow with M: {ratios:?}"
    );
}

/// T3 regime: higher compression -> fewer local centers -> faster,
/// monotone across the paper's sweep.
#[test]
fn table3_compression_reduces_centers_monotonically() {
    let data = paper_scaling_dataset(30_000, 5).unwrap();
    let mut centers = Vec::new();
    for c in [5.0f32, 10.0, 15.0, 20.0] {
        let cfg = PipelineConfig::builder()
            .compression(c)
            .final_k(60)
            .build()
            .unwrap();
        let r = SubclusterPipeline::new(cfg).run(&data).unwrap();
        centers.push(r.local_centers);
        let achieved = r.achieved_compression(30_000);
        assert!(
            achieved >= c as f64 * 0.5,
            "achieved compression {achieved} far below requested {c}"
        );
    }
    assert!(
        centers.windows(2).all(|w| w[1] < w[0]),
        "local centers must shrink with compression: {centers:?}"
    );
}

/// Quality guard across the compression sweep: inertia within 2x of
/// the traditional baseline even at c=20.
#[test]
fn compression_quality_degrades_gracefully() {
    let data = paper_scaling_dataset(20_000, 3).unwrap();
    let k = 40;
    let base = parsample::pipeline::traditional_kmeans_restarts(&data, k, 25, 0, 1).unwrap();
    for c in [5.0f32, 20.0] {
        let cfg = PipelineConfig::builder()
            .compression(c)
            .final_k(k)
            .weighted_global(true)
            .build()
            .unwrap();
        let r = SubclusterPipeline::new(cfg).run(&data).unwrap();
        assert!(
            r.inertia < base.inertia * 2.0,
            "c={c}: inertia {} vs baseline {}",
            r.inertia,
            base.inertia
        );
    }
}

/// Failure injection: non-finite data, degenerate configs, and
/// constant datasets must fail cleanly or produce sane output — never
/// panic or hang.
#[test]
fn failure_injection_degenerate_inputs() {
    use parsample::data::Dataset;
    // constant dataset: scaling collapses, but clustering must succeed
    let constant = Dataset::new(vec![2.5f32; 200], 2).unwrap();
    let cfg = PipelineConfig::builder()
        .final_k(2)
        .num_groups(3)
        .compression(2.0)
        .build()
        .unwrap();
    let r = SubclusterPipeline::new(cfg).run(&constant).unwrap();
    assert_eq!(r.counts.iter().sum::<u32>(), 100);

    // single point
    let single = Dataset::new(vec![1.0, 2.0], 2).unwrap();
    let cfg = PipelineConfig::builder()
        .final_k(1)
        .num_groups(1)
        .compression(1.0)
        .build()
        .unwrap();
    let r = SubclusterPipeline::new(cfg).run(&single).unwrap();
    assert_eq!(r.labels, vec![0]);

    // NaN rejected at dataset construction
    assert!(Dataset::new(vec![f32::NAN, 0.0], 2).is_err());
}

/// The three schemes agree on easy, well-separated data.
#[test]
fn schemes_agree_on_easy_data() {
    let data = make_blobs(&BlobSpec {
        num_points: 2000,
        num_clusters: 4,
        dims: 2,
        std: 0.02,
        extent: 20.0,
        seed: 13,
    })
    .unwrap();
    let truth = data.labels().unwrap().to_vec();
    for scheme in [Scheme::Equal, Scheme::Unequal, Scheme::Random] {
        let cfg = PipelineConfig::builder()
            .scheme(scheme)
            .final_k(4)
            .num_groups(5)
            .compression(5.0)
            .weighted_global(true)
            .build()
            .unwrap();
        let r = SubclusterPipeline::new(cfg).run(&data).unwrap();
        let ari = eval::ari(&r.labels, &truth).unwrap();
        assert!(ari > 0.99, "{scheme:?}: ari {ari} on trivially separable data");
    }
}

/// Determinism: identical config + data -> identical output.
#[test]
fn pipeline_is_deterministic() {
    let data = paper_scaling_dataset(10_000, 11).unwrap();
    let mk = || {
        let cfg = PipelineConfig::builder()
            .final_k(20)
            .compression(5.0)
            .seed(99)
            .build()
            .unwrap();
        SubclusterPipeline::new(cfg).run(&data).unwrap()
    };
    let a = mk();
    let b = mk();
    assert_eq!(a.centers, b.centers);
    assert_eq!(a.labels, b.labels);
    assert_eq!(a.inertia, b.inertia);
}
