//! Property-based invariant tests (proptest is not in the offline
//! vendor set, so this uses the crate's own seeded PRNG to sweep a
//! randomized case space — every failure reproduces from the printed
//! case seed).
//!
//! Covered invariants:
//!   partitioners  — disjoint cover, determinism, size law (equal)
//!   batcher       — point conservation through split/pack/unpack
//!   k-means       — inertia monotonicity, label-center consistency
//!   hungarian     — matching validity + optimality vs brute force
//!   metrics       — symmetry, identity, triangle inequality (metrics)
//!   json          — parse/emit round-trip on random values
//!   layout        — flatten/reconstruct inverse in both orders

use parsample::cluster::kmeans::{lloyd, KMeansConfig};
use parsample::cluster::{BoundsMode, InitMethod, KernelMode};
use parsample::coordinator::batcher::{local_k, Batcher};
use parsample::data::synthetic::{make_blobs, BlobSpec};
use parsample::data::{flatten, reconstruct, Dataset, MemoryOrder};
use parsample::distance::Metric;
use parsample::eval::hungarian::min_cost_assignment;
use parsample::partition::{Partitioner, Scheme};
use parsample::runtime::{Backend, NativeBackend};
use parsample::util::json::Json;
use parsample::util::rng::Pcg32;

const CASES: u64 = 60;

fn random_dataset(rng: &mut Pcg32) -> Dataset {
    let m = 2 + rng.below(300);
    let d = 1 + rng.below(6);
    let k_true = 1 + rng.below(8).min(m - 1);
    make_blobs(&BlobSpec {
        num_points: m,
        num_clusters: k_true.max(1),
        dims: d,
        std: 0.01 + rng.next_f32() * 0.5,
        extent: 0.5 + rng.next_f32() * 20.0,
        seed: rng.next_u64(),
    })
    .unwrap()
}

#[test]
fn prop_partitioners_produce_disjoint_cover() {
    for case in 0..CASES {
        let mut rng = Pcg32::new(case, 1);
        let data = random_dataset(&mut rng);
        let g = 1 + rng.below(12);
        for scheme in [Scheme::Equal, Scheme::Unequal, Scheme::Random] {
            // Partition::new validates cover+disjoint internally; also
            // check determinism across two runs
            let p1 = scheme.build(case).partition(&data, g).unwrap();
            let p2 = scheme.build(case).partition(&data, g).unwrap();
            assert_eq!(p1, p2, "case {case} scheme {scheme:?} not deterministic");
            assert_eq!(
                p1.sizes().iter().sum::<usize>(),
                data.len(),
                "case {case} {scheme:?}"
            );
        }
    }
}

#[test]
fn prop_equal_partitioner_size_law() {
    for case in 0..CASES {
        let mut rng = Pcg32::new(case, 2);
        let data = random_dataset(&mut rng);
        let g = 1 + rng.below(10);
        let p = Scheme::Equal.build(0).partition(&data, g).unwrap();
        let n = data.len().div_ceil(g.min(data.len()));
        for (i, s) in p.sizes().iter().enumerate() {
            if i + 1 < p.num_groups() {
                assert_eq!(*s, n, "case {case}: non-terminal shell size");
            } else {
                assert!(*s <= n, "case {case}: terminal shell too large");
            }
        }
    }
}

#[test]
fn prop_batcher_conserves_points() {
    for case in 0..CASES {
        let mut rng = Pcg32::new(case, 3);
        let data = random_dataset(&mut rng);
        let g = 1 + rng.below(8);
        let c = 1.0 + rng.next_f32() * 9.0;
        let partition = Scheme::Unequal.build(0).partition(&data, g).unwrap();
        let dispatches =
            Batcher::plan_exact(&data, partition.groups(), c, 5, 64).unwrap();
        // every point appears in exactly one dispatch slot
        let mut seen = vec![false; data.len()];
        for d in &dispatches {
            for gs in &d.groups {
                assert_eq!(gs.k, local_k(gs.n, c), "case {case}");
                for &i in &gs.indices {
                    assert!(!seen[i], "case {case}: point {i} duplicated");
                    seen[i] = true;
                }
            }
        }
        assert!(seen.iter().all(|&s| s), "case {case}: point lost");
    }
}

#[test]
fn prop_native_backend_counts_match_weights() {
    for case in 0..CASES / 2 {
        let mut rng = Pcg32::new(case, 4);
        let data = random_dataset(&mut rng);
        let g = 1 + rng.below(5);
        let partition = Scheme::Random.build(case).partition(&data, g).unwrap();
        let dispatches =
            Batcher::plan_exact(&data, partition.groups(), 3.0, 4, 128).unwrap();
        let backend = NativeBackend::serial();
        for d in &dispatches {
            let out = backend.run_batch(&d.batch).unwrap();
            let total: f32 = out.counts.iter().sum();
            let expect: f32 = d.batch.weights.iter().sum();
            assert!((total - expect).abs() < 0.5, "case {case}: {total} vs {expect}");
            // labels in range
            assert!(out.labels.iter().all(|&l| (l as usize) < d.batch.k));
        }
    }
}

#[test]
fn prop_kmeans_inertia_monotone_in_iterations() {
    for case in 0..CASES / 2 {
        let mut rng = Pcg32::new(case, 5);
        let data = random_dataset(&mut rng);
        let k = 1 + rng.below(data.len().min(10));
        let mut prev = f64::INFINITY;
        for iters in [1usize, 3, 6, 12] {
            let cfg = KMeansConfig {
                k,
                max_iters: iters,
                tol: 0.0,
                init: InitMethod::FirstK,
                seed: 0,
                workers: 1,
                bounds: BoundsMode::Hamerly,
                kernel: KernelMode::session_default(),
                ..Default::default()
            };
            let r = lloyd(data.as_slice(), data.dims(), &cfg).unwrap();
            assert!(
                r.inertia <= prev * (1.0 + 1e-5) + 1e-6,
                "case {case}: inertia rose {prev} -> {}",
                r.inertia
            );
            prev = r.inertia;
        }
    }
}

#[test]
fn prop_kmeans_labels_are_nearest_center() {
    for case in 0..CASES / 2 {
        let mut rng = Pcg32::new(case, 6);
        let data = random_dataset(&mut rng);
        let k = 1 + rng.below(data.len().min(8));
        let cfg = KMeansConfig { k, ..Default::default() };
        let r = lloyd(data.as_slice(), data.dims(), &cfg).unwrap();
        for i in 0..data.len() {
            let (c, _) = parsample::distance::nearest_sq(data.row(i), &r.centers, data.dims());
            assert_eq!(r.labels[i], c as u32, "case {case} point {i}");
        }
    }
}

#[test]
fn prop_hungarian_optimal_vs_bruteforce_4x4() {
    fn perms(xs: Vec<usize>) -> Vec<Vec<usize>> {
        if xs.len() <= 1 {
            return vec![xs];
        }
        let mut out = Vec::new();
        for i in 0..xs.len() {
            let mut rest = xs.clone();
            let x = rest.remove(i);
            for mut p in perms(rest) {
                p.insert(0, x);
                out.push(p);
            }
        }
        out
    }
    for case in 0..CASES {
        let mut rng = Pcg32::new(case, 7);
        let n = 2 + rng.below(3); // 2..4
        let cost: Vec<f64> = (0..n * n).map(|_| (rng.below(100)) as f64).collect();
        let assign = min_cost_assignment(&cost, n, n);
        let total: f64 = assign.iter().enumerate().map(|(r, &c)| cost[r * n + c]).sum();
        let best = perms((0..n).collect())
            .into_iter()
            .map(|p| p.iter().enumerate().map(|(r, &c)| cost[r * n + c]).sum::<f64>())
            .fold(f64::INFINITY, f64::min);
        assert_eq!(total, best, "case {case}: {cost:?}");
    }
}

#[test]
fn prop_metric_axioms() {
    let metrics = [
        Metric::Euclidean,
        Metric::Manhattan,
        Metric::Chebyshev,
        Metric::Minkowski(3.0),
    ];
    for case in 0..CASES {
        let mut rng = Pcg32::new(case, 8);
        let d = 1 + rng.below(6);
        let gen = |rng: &mut Pcg32| -> Vec<f32> {
            (0..d).map(|_| rng.uniform(-10.0, 10.0)).collect()
        };
        let (a, b, c) = (gen(&mut rng), gen(&mut rng), gen(&mut rng));
        for m in metrics {
            let ab = m.dist(&a, &b);
            let ba = m.dist(&b, &a);
            assert!((ab - ba).abs() < 1e-4, "case {case} {m:?} symmetry");
            assert!(m.dist(&a, &a) < 1e-6, "case {case} {m:?} identity");
            let ac = m.dist(&a, &c);
            let cb = m.dist(&c, &b);
            assert!(
                ab <= ac + cb + 1e-3,
                "case {case} {m:?} triangle: {ab} > {ac} + {cb}"
            );
        }
    }
}

#[test]
fn prop_json_roundtrip() {
    fn random_json(rng: &mut Pcg32, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 1),
            2 => Json::Num((rng.below(2_000_001) as f64 - 1e6) / 8.0),
            3 => Json::Str(
                (0..rng.below(12))
                    .map(|_| char::from_u32(0x20 + rng.below(0x5e) as u32).unwrap())
                    .collect(),
            ),
            4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for case in 0..CASES * 4 {
        let mut rng = Pcg32::new(case, 9);
        let v = random_json(&mut rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}: {text}"));
        assert_eq!(back, v, "case {case}: {text}");
    }
}

#[test]
fn prop_layout_roundtrip() {
    for case in 0..CASES {
        let mut rng = Pcg32::new(case, 10);
        let data = random_dataset(&mut rng);
        let take = 1 + rng.below(data.len());
        let indices: Vec<usize> = rng.sample_indices(data.len(), take);
        let row = flatten(&data, &indices, MemoryOrder::RowMajor);
        for order in [MemoryOrder::RowMajor, MemoryOrder::ColMajor] {
            let flat = flatten(&data, &indices, order);
            let back = reconstruct(&flat, indices.len(), data.dims(), order).unwrap();
            assert_eq!(back, row, "case {case} {order:?}");
        }
    }
}

#[test]
fn prop_pipeline_label_center_consistency() {
    use parsample::pipeline::{PipelineConfig, SubclusterPipeline};
    for case in 0..8 {
        let mut rng = Pcg32::new(case, 11);
        let data = random_dataset(&mut rng);
        let k = 1 + rng.below(data.len().min(6));
        let cfg = PipelineConfig::builder()
            .final_k(k)
            .num_groups(1 + rng.below(6))
            .compression(1.0 + rng.next_f32() * 4.0)
            .seed(case)
            .build()
            .unwrap();
        match SubclusterPipeline::new(cfg).run(&data) {
            Ok(r) => {
                assert_eq!(r.labels.len(), data.len(), "case {case}");
                assert_eq!(
                    r.counts.iter().sum::<u32>() as usize,
                    data.len(),
                    "case {case}"
                );
                // achieved compression is bounded by the requested one
                assert!(r.local_centers <= data.len(), "case {case}");
            }
            // legitimately impossible configs (too few local centers)
            Err(parsample::Error::Cluster(_)) => {}
            Err(e) => panic!("case {case}: unexpected error {e}"),
        }
    }
}
