// Clean taint fixture: everything reachable from the contract region
// is either contract-covered itself or an audited leaf.

// CONTRACT: bit-exact — fixture root region.
pub fn tk_root(xs: &[f32]) -> f32 {
    tk_covered(xs) + tk_boundary(xs.len())
}

// CONTRACT: bit-exact — covered helper, fold order fixed.
pub fn tk_covered(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |acc, x| acc + x)
}

// CONTRACT: bit-exact (leaf) — audited boundary: returns a value
// derived only from its argument; nothing beyond it is walked.
pub fn tk_boundary(n: usize) -> f32 {
    tk_unwalked(n)
}

pub fn tk_unwalked(n: usize) -> f32 {
    n as f32
}
