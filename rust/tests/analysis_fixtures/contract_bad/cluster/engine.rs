//! CONTRACT: bit-exact — labels must not depend on iteration order.

use std::collections::HashMap;
use std::time::Instant;

pub fn histogram(labels: &[usize]) -> HashMap<usize, usize> {
    let start = Instant::now();
    let mut h = HashMap::new();
    for &l in labels {
        *h.entry(l).or_insert(0) += 1;
    }
    let _ = start;
    h
}

pub fn total(xs: &[f32]) -> f32 {
    xs.iter().sum()
}
