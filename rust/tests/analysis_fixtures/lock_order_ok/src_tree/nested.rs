// Clean lock-order fixture: one nesting, declared in this subtree's
// analysis/locks.toml (lint the `lock_order_ok` directory as a root).

use std::sync::Mutex;

pub struct LoState {
    pub lo_outer: Mutex<u32>,
    pub lo_inner: Mutex<u32>,
}

pub fn lo_nest(s: &LoState) -> u32 {
    let go = s.lo_outer.lock().expect("lo_outer poisoned");
    let gi = s.lo_inner.lock().expect("lo_inner poisoned");
    *go + *gi
}
