// Blocking-under-lock fixture: a channel recv directly under a held
// guard, and the same by calling through a helper.

use std::sync::mpsc::Receiver;
use std::sync::Mutex;

pub fn bk_direct(m: &Mutex<Receiver<u32>>) -> u32 {
    let rx = m.lock().expect("rx poisoned");
    rx.recv().unwrap_or(0)
}

pub fn bk_via_call(m: &Mutex<u32>, rx: &Receiver<u32>) -> u32 {
    let g = m.lock().expect("counter poisoned");
    *g + bk_drain(rx)
}

fn bk_drain(rx: &Receiver<u32>) -> u32 {
    rx.recv().unwrap_or(0)
}
