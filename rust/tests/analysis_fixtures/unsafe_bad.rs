//! Fixture: `unsafe` with no safety comment anywhere near it.

pub fn peek(xs: &[f32]) -> f32 {
    unsafe { *xs.get_unchecked(0) }
}
