//! Fixture: condvar wait re-checked in a `while` loop.

use std::sync::{Condvar, Mutex};

pub fn await_ready(lock: &Mutex<bool>, cv: &Condvar) {
    let mut ready = lock.lock().expect("state lock poisoned");
    while !*ready {
        ready = cv.wait(ready).expect("state lock poisoned");
    }
    *ready = false;
}
