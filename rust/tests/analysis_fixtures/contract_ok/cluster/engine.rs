//! CONTRACT: bit-exact — fixture for a clean determinism path.

/// Deterministic fold in index order.
pub fn total(xs: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for &x in xs {
        acc += x;
    }
    acc
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;

    #[test]
    fn tests_may_use_maps() {
        let mut h = HashMap::new();
        h.insert(1usize, 2usize);
        assert_eq!(h[&1], 2);
    }
}
