// Lock-order fixture: two fns acquire the same two mutexes in
// opposite orders — both nestings are undeclared (no locks.toml in
// this subtree) and together they form a cycle.

use std::sync::Mutex;

pub struct LcState {
    pub lc_a: Mutex<u32>,
    pub lc_b: Mutex<u32>,
}

pub fn lc_forward(s: &LcState) -> u32 {
    let ga = s.lc_a.lock().expect("lc_a poisoned");
    let gb = s.lc_b.lock().expect("lc_b poisoned");
    *ga + *gb
}

pub fn lc_backward(s: &LcState) -> u32 {
    let gb = s.lc_b.lock().expect("lc_b poisoned");
    let ga = s.lc_a.lock().expect("lc_a poisoned");
    *ga + *gb
}
