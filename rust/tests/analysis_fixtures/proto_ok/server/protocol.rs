//! Fixture: every wire command is parsed, encoded, and roundtripped.

pub struct WireCommand {
    pub cmd: &'static str,
    pub encode: &'static str,
    pub tests: &'static [&'static str],
}

pub const WIRE_COMMANDS: &[WireCommand] = &[
    WireCommand { cmd: "ping", encode: "encode_pong", tests: &["ping_roundtrip"] },
    WireCommand { cmd: "add", encode: "encode_add", tests: &["add_roundtrip"] },
];

pub fn parse_request(line: &str) -> Result<&'static str, String> {
    match line {
        "ping" => Ok("pong"),
        "add" => Ok("add"),
        other => Err(format!("unknown cmd {other}")),
    }
}

pub fn encode_pong() -> String {
    "pong".to_string()
}

pub fn encode_add(v: u64) -> String {
    format!("{v}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ping_roundtrip() {
        assert_eq!(parse_request("ping"), Ok("pong"));
        assert_eq!(encode_pong(), "pong");
    }

    #[test]
    fn add_roundtrip() {
        assert_eq!(encode_add(3), "3");
        assert!(parse_request("add").is_ok());
    }
}
