//! Fixture: typed error paths only; tests may panic freely.

pub fn route(cmd: &str) -> Result<usize, String> {
    cmd.parse::<usize>().map_err(|e| format!("bad cmd: {e}"))
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_unwrap() {
        assert_eq!(super::route("3").unwrap(), 3);
    }
}
