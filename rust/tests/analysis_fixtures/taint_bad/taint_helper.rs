// Taint fixture: a contract-marked fn calls an unmarked same-module
// helper — the helper is transitively on the bit-exact contract and
// must be flagged.

// CONTRACT: bit-exact — fixture root region.
pub fn tb_root(xs: &[f32]) -> f32 {
    tb_helper(xs)
}

pub fn tb_helper(xs: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for x in xs {
        acc += *x;
    }
    acc
}
