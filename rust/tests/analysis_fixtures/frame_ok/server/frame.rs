//! Fixture: every frame command is parsed, encoded, and roundtripped.

pub struct FrameCommand {
    pub cmd: &'static str,
    pub encode: &'static str,
    pub tests: &'static [&'static str],
}

pub const FRAME_COMMANDS: &[FrameCommand] = &[
    FrameCommand { cmd: "ping", encode: "encode_pong_frame", tests: &["ping_frame_roundtrip"] },
    FrameCommand { cmd: "predict", encode: "encode_labels_frame", tests: &["labels_roundtrip"] },
];

pub fn opcode_of(name: &str) -> Result<u8, String> {
    match name {
        "ping" => Ok(0x01),
        "predict" => Ok(0x02),
        other => Err(format!("unknown frame command {other}")),
    }
}

pub fn encode_pong_frame() -> Vec<u8> {
    vec![0x81]
}

pub fn encode_labels_frame(labels: &[u32]) -> Vec<u8> {
    let mut out = vec![0x82];
    for label in labels {
        out.extend_from_slice(&label.to_le_bytes());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ping_frame_roundtrip() {
        assert_eq!(opcode_of("ping"), Ok(0x01));
        assert_eq!(encode_pong_frame(), vec![0x81]);
    }

    #[test]
    fn labels_roundtrip() {
        assert_eq!(encode_labels_frame(&[1]), vec![0x82, 1, 0, 0, 0]);
        assert!(opcode_of("predict").is_ok());
    }
}
