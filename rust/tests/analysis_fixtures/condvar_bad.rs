//! Fixture: condvar wait behind an `if` — wakeups are spurious.

use std::sync::{Condvar, Mutex};

pub fn await_ready(lock: &Mutex<bool>, cv: &Condvar) {
    let mut ready = lock.lock().expect("state lock poisoned");
    if !*ready {
        ready = cv.wait(ready).expect("state lock poisoned");
    }
    *ready = false;
}
