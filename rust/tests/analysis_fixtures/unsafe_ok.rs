//! Fixture: both `unsafe` sites carry safety comments.

/// Reads the first element without a bounds check.
// SAFETY: callers guarantee `xs` is non-empty.
pub unsafe fn head(xs: &[f32]) -> f32 {
    *xs.get_unchecked(0)
}

pub fn first(xs: &[f32]) -> f32 {
    assert!(!xs.is_empty());
    // SAFETY: the assert above keeps index 0 in bounds.
    unsafe { head(xs) }
}
