//! Fixture: wire table drifted from the parse/encode/test reality.

pub struct WireCommand {
    pub cmd: &'static str,
    pub encode: &'static str,
    pub tests: &'static [&'static str],
}

pub const WIRE_COMMANDS: &[WireCommand] = &[
    WireCommand { cmd: "ping", encode: "encode_pong", tests: &[] },
    WireCommand { cmd: "stats", encode: "encode_stats", tests: &["stats_roundtrip"] },
    WireCommand { cmd: "reset", encode: "encode_reset", tests: &["reset_roundtrip"] },
];

pub fn parse_request(line: &str) -> Result<&'static str, String> {
    match line {
        "ping" => Ok("pong"),
        "stats" => Ok("stats"),
        "drop" => Ok("drop"),
        other => Err(format!("unknown cmd {other}")),
    }
}

pub fn encode_pong() -> String {
    "pong".to_string()
}

pub fn encode_stats() -> String {
    "stats".to_string()
}

pub fn reset_roundtrip() {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_roundtrip() {
        assert_eq!(encode_stats(), "stats");
        assert!(parse_request("stats").is_ok());
    }
}
