//! Fixture: frame table drifted from the opcode/encode/test reality.

pub struct FrameCommand {
    pub cmd: &'static str,
    pub encode: &'static str,
    pub tests: &'static [&'static str],
}

pub const FRAME_COMMANDS: &[FrameCommand] = &[
    FrameCommand { cmd: "ping", encode: "encode_pong_frame", tests: &[] },
    FrameCommand { cmd: "stats", encode: "encode_stats_frame", tests: &["stats_frame_roundtrip"] },
    FrameCommand { cmd: "reset", encode: "encode_reset_frame", tests: &["reset_frame_roundtrip"] },
];

pub fn opcode_of(name: &str) -> Result<u8, String> {
    match name {
        "ping" => Ok(0x01),
        "stats" => Ok(0x03),
        "drop" => Ok(0x04),
        other => Err(format!("unknown frame command {other}")),
    }
}

pub fn encode_pong_frame() -> Vec<u8> {
    vec![0x81]
}

pub fn encode_stats_frame() -> Vec<u8> {
    vec![0x83]
}

pub fn reset_frame_roundtrip() {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_frame_roundtrip() {
        assert_eq!(encode_stats_frame(), vec![0x83]);
        assert!(opcode_of("stats").is_ok());
    }
}
