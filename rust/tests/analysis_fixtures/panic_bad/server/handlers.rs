//! Fixture: panic paths on the request surface.

pub fn route(cmd: &str) -> usize {
    let code = cmd.parse::<usize>().unwrap();
    if code > 9 {
        panic!("bad code");
    }
    code
}

pub fn reply(code: usize) -> String {
    std::str::from_utf8(&[b'0' + code as u8]).expect("ascii").to_string()
}

pub fn later() -> usize {
    todo!()
}
