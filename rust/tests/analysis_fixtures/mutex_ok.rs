//! Fixture: poisoning documented or handled at every lock.

use std::sync::Mutex;

pub fn bump(counter: &Mutex<u64>) -> u64 {
    let mut g = counter.lock().expect("counter lock poisoned");
    *g += 1;
    *g
}

pub fn read(counter: &Mutex<u64>) -> u64 {
    *counter.lock().unwrap_or_else(|p| p.into_inner())
}
