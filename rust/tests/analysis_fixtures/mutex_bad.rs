//! Fixture: lock result used without a poisoning story.

use std::sync::Mutex;

pub fn bump(counter: &Mutex<u64>) -> u64 {
    let mut g = counter.lock().expect("counter");
    *g += 1;
    *g
}
