//! Fixture: a determinism-path file with no contract annotation.

pub fn assign(x: f32) -> usize {
    if x > 0.0 {
        1
    } else {
        0
    }
}
